"""Fault-tolerant sweep runtime: deterministic fault injection, retry and
quarantine semantics, crash-safe stores, and the golden bit-identity
invariant — under any seeded fault schedule within the retry budget, the
healthy record set equals a fault-free serial run on every backend.

Fast deterministic tests carry the tier1 marker; the process-pool and
crash-restart tests (real worker kills, real SIGKILL of a shard
subprocess) are unmarked and run with the full suite / ``make faults``.
"""
import importlib.util
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.api import (BudgetPolicy, DesignSpace, ExplorationSession,
                       FailureRecord, FaultInjector, GAConfig,
                       HeartbeatMonitor, InjectedFault, PointOutcome,
                       ResultStore, RetryPolicy, StoreCorruptionError,
                       StoreLockError, build_manifest, merge_stores,
                       run_shard)
from repro.api.resilience import _unit_hash
from repro.api.session import _demo_records
from repro.configs.paper_workloads import fsrcnn
from repro.hw.catalog import mc_hom_tpu, sc_eye, sc_tpu

tier1 = pytest.mark.tier1

GA = GAConfig(pop_size=4, generations=2)


def _space(**kw):
    base = dict(workloads={"fsrcnn": fsrcnn()},
                archs={"SC:TPU": sc_tpu, "SC:Eye": sc_eye,
                       "MC:HomTPU": mc_hom_tpu},
                granularities=["layer", ("tile", 8, 1)], ga=GA)
    base.update(kw)
    return DesignSpace(**base)


def _metric_seq(records):
    return [(r.key, r.latency_cc, r.energy_pj, r.edp, r.allocation)
            for r in records]


def _metric_set(records):
    return set(_metric_seq(records))


@pytest.fixture(scope="module")
def reference():
    """Fault-free serial run of the standard test space (the golden set)."""
    return ExplorationSession().run(_space())


# ---------------------------------------------------------------------------
# fault injector / retry policy: pure, seeded, deterministic
# ---------------------------------------------------------------------------

@tier1
def test_unit_hash_is_pure_and_uniformish():
    draws = [_unit_hash(0, "exception", f"k{i}", 0) for i in range(200)]
    assert draws == [_unit_hash(0, "exception", f"k{i}", 0)
                     for i in range(200)]
    assert all(0.0 <= d < 1.0 for d in draws)
    # a 10% rate should hit *roughly* 10% of keys — loose sanity bound
    assert 5 <= sum(d < 0.1 for d in draws) <= 40


@tier1
def test_injector_plan_is_deterministic_and_gated():
    inj = FaultInjector(seed=3, exception_rate=0.5, kill_rate=0.25,
                        delay_rate=0.25, max_faults_per_point=2)
    again = FaultInjector.from_dict(inj.to_dict())
    keys = [f"point{i}" for i in range(50)]
    plans = [[inj.plan(k, a) for a in range(4)] for k in keys]
    assert plans == [[again.plan(k, a) for a in range(4)] for k in keys]
    # the gate guarantees recovery: attempts >= max_faults_per_point are clean
    assert all(p[2] is None and p[3] is None for p in plans)
    # kill outranks exception outranks delay: at most one fault per attempt
    assert {kind for p in plans for kind in p} <= {
        None, "kill", "exception", "delay"}


@tier1
def test_injector_fire_raises_and_degrades_kill():
    inj = FaultInjector(seed=0, exception_rate=1.0)
    with pytest.raises(InjectedFault):
        inj.fire("k", 0)
    killer = FaultInjector(seed=0, kill_rate=1.0)
    with pytest.raises(InjectedFault, match="degraded"):
        killer.fire("k", 0, allow_kill=False)   # serial: never SIGKILL
    assert FaultInjector(seed=0).plan("k", 0) is None


@tier1
def test_retry_policy_backoff_is_seeded_not_wall_clock():
    p = RetryPolicy(max_attempts=4, backoff_s=0.5, jitter=0.8, seed=11)
    delays = [p.delay_s("k", a) for a in (1, 2, 3)]
    assert delays == [RetryPolicy.from_dict(p.to_dict()).delay_s("k", a)
                      for a in (1, 2, 3)]
    assert all(d > 0 for d in delays)
    assert p.delay_s("k", 1) != p.delay_s("other", 1)  # per-key jitter
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


@tier1
def test_failure_record_and_outcome_round_trip():
    f = FailureRecord(key="k", workload="w", arch="A",
                      error_type="InjectedFault", message="boom",
                      traceback="tb", attempts=3, spec={"workload": "w"})
    assert FailureRecord.from_dict(json.loads(json.dumps(f.to_dict()))) == f
    o = PointOutcome(key="k", failure=f, n_retries=2)
    back = PointOutcome.from_jsonable(json.loads(json.dumps(o.to_jsonable())))
    assert (back.ok, back.failure, back.n_retries) == (False, f, 2)


# ---------------------------------------------------------------------------
# golden invariant: healthy records bit-identical to fault-free serial
# ---------------------------------------------------------------------------

@tier1
def test_serial_faulted_run_is_bit_identical(reference):
    inj = FaultInjector(seed=1, exception_rate=0.5, max_faults_per_point=2)
    sess = ExplorationSession(retry_policy=RetryPolicy(max_attempts=3),
                              fault_injector=inj)
    sweep = sess.run(_space())
    assert _metric_seq(sweep.records) == _metric_seq(reference.records)
    assert sweep.n_failed == 0 and not sweep.failures
    assert sweep.n_retried > 0          # the schedule actually fired


@tier1
def test_store_corruption_faults_recover_bit_identical(tmp_path, reference):
    inj = FaultInjector(seed=5, corrupt_rate=0.5, max_faults_per_point=2)
    sess = ExplorationSession(cache_dir=str(tmp_path),
                              retry_policy=RetryPolicy(max_attempts=3),
                              fault_injector=inj)
    sweep = sess.run(_space())
    assert _metric_seq(sweep.records) == _metric_seq(reference.records)
    assert sweep.n_failed == 0 and sweep.n_retried > 0
    # the store on disk is clean after recovery: reload sees every record
    reloaded = ResultStore(str(tmp_path))
    assert _metric_set(reloaded.values()) == _metric_set(reference.records)
    assert reloaded.verify()["n_records"] == len(reference.records)


@tier1
def test_budget_exhaustion_quarantines_not_aborts(reference):
    # every attempt faults and there is no retry budget: all quarantined
    sess = ExplorationSession(
        fault_injector=FaultInjector(seed=0, exception_rate=1.0))
    sweep = sess.run(_space())
    assert len(sweep.records) == 0
    assert sweep.n_failed == len(reference.records)
    assert sweep.n_cancelled == 0
    assert all(f.error_type == "InjectedFault" and f.attempts == 1
               for f in sweep.failures)
    assert {f.key for f in sweep.failures} == \
        {r.key for r in reference.records}


@tier1
def test_partial_quarantine_keeps_healthy_points(reference):
    # ~half the points fault on every attempt -> quarantined; rest identical
    inj = FaultInjector(seed=9, exception_rate=0.5)   # no gate: never recovers
    sess = ExplorationSession(retry_policy=RetryPolicy(max_attempts=2),
                              fault_injector=inj)
    sweep = sess.run(_space())
    assert 0 < sweep.n_failed < len(reference.records)
    assert len(sweep.records) + sweep.n_failed == len(reference.records)
    ref = {r.key: m for r, m in zip(reference.records,
                                    _metric_seq(reference.records))}
    assert all(m == ref[r.key]
               for r, m in zip(sweep.records, _metric_seq(sweep.records)))
    assert all(f.attempts == 2 and f.traceback for f in sweep.failures)


@tier1
def test_run_async_with_policies_deterministic_under_faults(reference):
    def stream_with(sess):
        return list(sess.run_async(_space(),
                                   policies=[BudgetPolicy(max_records=3)]))

    clean = stream_with(ExplorationSession())
    inj = FaultInjector(seed=4, exception_rate=0.6, max_faults_per_point=1)
    faulted = stream_with(ExplorationSession(
        retry_policy=RetryPolicy(max_attempts=2), fault_injector=inj))
    assert _metric_seq(faulted) == _metric_seq(clean)
    assert len(faulted) == 3


@tier1
def test_policies_see_failure_events():
    budget = BudgetPolicy(max_failures=2)
    sess = ExplorationSession(
        fault_injector=FaultInjector(seed=0, exception_rate=1.0))
    sweep = sess.run(_space(), policies=[budget])
    assert sweep.n_failed == 2
    assert sweep.stop_reason == "budget: 2 quarantined points"
    # vanilla policies ignore failures (base update_failure is a no-op)
    sess2 = ExplorationSession(
        fault_injector=FaultInjector(seed=0, exception_rate=1.0))
    sweep2 = sess2.run(_space(), policies=[BudgetPolicy(max_records=99)])
    assert sweep2.stop_reason is None


@tier1
def test_heartbeat_monitor_counts_and_finalizes(tmp_path):
    hb_path = str(tmp_path / "hb.json")
    monitor = HeartbeatMonitor(hb_path, total=4)
    sess = ExplorationSession(
        retry_policy=RetryPolicy(max_attempts=2),
        fault_injector=FaultInjector(seed=9, exception_rate=0.5))
    sweep = sess.run(_space(), policies=[monitor])
    beat = json.load(open(hb_path))
    assert beat["done"] == len(sweep.records)
    assert beat["failed"] == sweep.n_failed > 0
    monitor.finalize("done")
    assert json.load(open(hb_path))["status"] == "done"


# ---------------------------------------------------------------------------
# crash-safe stores: torn tails, mid-file corruption, locking
# ---------------------------------------------------------------------------

def _seeded_store(path) -> ResultStore:
    store = ResultStore(str(path))
    for r in _demo_records():
        store.put(r)
    return store


@tier1
def test_torn_tail_is_dropped_and_truncated(tmp_path):
    store = _seeded_store(tmp_path / "s")
    store.append_torn(json.dumps(_demo_records()[0].to_dict()) + "\n")
    size_torn = os.path.getsize(store.path)
    reloaded = ResultStore(str(tmp_path / "s"))
    assert len(reloaded) == 3                     # torn line dropped...
    assert os.path.getsize(store.path) < size_torn   # ...and truncated away
    # the next append starts on a clean line: no interleaving with the tear
    reloaded.put(_demo_records()[0])
    assert ResultStore(str(tmp_path / "s")).verify()["torn_tail"] == 0


@tier1
def test_midfile_corruption_raises_unless_repaired(tmp_path):
    store = _seeded_store(tmp_path / "s")
    lines = open(store.path).read().splitlines(True)
    lines.insert(1, "NOT JSON {{{\n")
    lines.insert(3, '{"valid_json": "but not a record"}\n')
    with open(store.path, "w") as f:
        f.writelines(lines)
    with pytest.raises(StoreCorruptionError, match="malformed"):
        ResultStore(str(tmp_path / "s"))
    with pytest.raises(StoreCorruptionError):
        ResultStore.verify_path(str(tmp_path / "s"))
    with pytest.warns(RuntimeWarning, match="quarantined 2"):
        repaired = ResultStore(str(tmp_path / "s"), repair=True)
    assert len(repaired) == 3
    bad = open(store.path + ".bad").read()
    assert "NOT JSON" in bad and "valid_json" in bad
    # the rewritten file is clean: strict reload now succeeds
    assert len(ResultStore(str(tmp_path / "s"))) == 3


@tier1
def test_verify_reports_counts_and_torn_tail(tmp_path):
    store = _seeded_store(tmp_path / "s")
    store.put_failure(FailureRecord(
        key="zz", workload="w", arch="A", error_type="X", message="m",
        traceback="t", attempts=1))
    assert store.verify() == {"n_records": 3, "n_failures": 1,
                              "torn_tail": 0}
    store.append_torn("garbage-without-newline")
    assert ResultStore.verify_path(str(tmp_path / "s"))["torn_tail"] == 1


@tier1
def test_concurrent_appends_do_not_interleave(tmp_path):
    # two handles on one store file, alternating appends: every line lands
    # whole (single O_APPEND write under an advisory lock)
    a = ResultStore(str(tmp_path / "s"))
    b = ResultStore(str(tmp_path / "s"))
    r0, r1, r2 = _demo_records()
    for rec in (r0, r1, r2):
        a.put(rec)
        b.put(rec)
    report = ResultStore.verify_path(str(tmp_path / "s"))
    assert report == {"n_records": 6, "n_failures": 0, "torn_tail": 0}
    assert len(ResultStore(str(tmp_path / "s"))) == 3   # dedup by key


@tier1
def test_lock_failure_errors_loudly(tmp_path, monkeypatch):
    import repro.api.session as session_mod

    def deny(fd, op):
        raise OSError("lock denied")

    store = _seeded_store(tmp_path / "s")
    monkeypatch.setattr(session_mod.fcntl, "flock", deny)
    with pytest.raises(StoreLockError, match="lock"):
        store.put(_demo_records()[0])


@tier1
def test_failures_sidecar_round_trip_and_supersession(tmp_path):
    store = ResultStore(str(tmp_path / "s"))
    r0, r1, _ = _demo_records()
    fail_r1 = FailureRecord(key=r1.key, workload=r1.workload, arch=r1.arch,
                            error_type="InjectedFault", message="boom",
                            traceback="tb", attempts=2)
    store.put(r0)
    store.put_failure(fail_r1)
    store.put_failure(FailureRecord(  # stale: healthy record already exists
        key=r0.key, workload=r0.workload, arch=r0.arch, error_type="X",
        message="m", traceback="t", attempts=1))
    assert [f.key for f in store.failures()] == [r1.key]
    reloaded = ResultStore(str(tmp_path / "s"))
    assert [f.key for f in reloaded.failures()] == [r1.key]
    # a later healthy record supersedes the persisted failure
    reloaded.put(r1)
    assert reloaded.failures() == []
    assert ResultStore(str(tmp_path / "s")).failures() == []


@tier1
def test_merge_folds_failures_first_wins(tmp_path):
    r0, r1, r2 = _demo_records()
    a = ResultStore(str(tmp_path / "a"))
    a.put(r0)
    a.put_failure(FailureRecord(key=r1.key, workload=r1.workload,
                                arch=r1.arch, error_type="A", message="first",
                                traceback="t", attempts=1))
    b = ResultStore(str(tmp_path / "b"))
    b.put(r2)
    b.put_failure(FailureRecord(key=r1.key, workload=r1.workload,
                                arch=r1.arch, error_type="B", message="second",
                                traceback="t", attempts=3))
    merged = ResultStore.merge(a, b)
    assert {r.key for r in merged.values()} == {r0.key, r2.key}
    assert [f.message for f in merged.failures()] == ["first"]
    # a healthy record for the key in any source supersedes every failure
    c = ResultStore(str(tmp_path / "c"))
    c.put(r1)
    healthy = ResultStore.merge(a, b, c)
    assert len(healthy) == 3 and healthy.failures() == []


@tier1
def test_merge_accepts_failures_only_shard(tmp_path):
    a = ResultStore(str(tmp_path / "a"))   # every point quarantined
    r0, _, _ = _demo_records()
    a.put_failure(FailureRecord(key=r0.key, workload=r0.workload,
                                arch=r0.arch, error_type="X", message="m",
                                traceback="t", attempts=1))
    merged = merge_stores(None, str(tmp_path / "a"))
    assert len(merged) == 0 and len(merged.failures()) == 1


# ---------------------------------------------------------------------------
# run_shard: retries knob, heartbeat, quarantine exit path
# ---------------------------------------------------------------------------

def _load_tool(name):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(root, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@tier1
def test_run_shard_retries_and_heartbeat(tmp_path, reference):
    m = build_manifest(_space())
    inj = FaultInjector(seed=2, exception_rate=0.6, max_faults_per_point=2)
    stores = []
    for k in range(2):
        out = str(tmp_path / f"shard{k}")
        sweep = run_shard(m, cache_dir=out, shard=(k, 2), retries=2,
                          fault_injector=inj,
                          heartbeat=str(tmp_path / f"hb{k}.json"))
        assert sweep.n_failed == 0
        beat = json.load(open(tmp_path / f"hb{k}.json"))
        assert beat["status"] == "done" and beat["done"] == len(sweep)
        assert (beat["shard_index"], beat["n_shards"]) == (k, 2)
        stores.append(out)
    merged = merge_stores(str(tmp_path / "merged"), *stores)
    assert _metric_set(merged.values()) == _metric_set(reference.records)


@tier1
def test_run_shard_cli_exit_3_on_quarantine(tmp_path, monkeypatch, capsys):
    import repro.api.distributed as dist
    cli = _load_tool("run_shard")
    mpath = str(tmp_path / "sweep.json")
    build_manifest(_space()).save(mpath)

    real = dist.run_shard

    def faulted(*args, **kw):   # the CLI has no injector flag by design
        kw["fault_injector"] = FaultInjector(seed=0, exception_rate=1.0)
        return real(*args, **kw)

    monkeypatch.setattr(dist, "run_shard", faulted)
    rc = cli.main([mpath, "--out", str(tmp_path / "sh")])
    assert rc == 3
    err = capsys.readouterr().err
    assert "QUARANTINED" in err and "InjectedFault" in err
    assert os.path.exists(tmp_path / "sh" / "failures.jsonl")
    assert os.path.exists(tmp_path / "sh" / "heartbeat.json")


@tier1
def test_merge_cli_verify_and_repair(tmp_path, capsys):
    cli = _load_tool("merge_stores")
    _seeded_store(tmp_path / "a")
    rc = cli.main([str(tmp_path / "m"), str(tmp_path / "a"), "--verify"])
    assert rc == 0
    assert "ok" in capsys.readouterr().out
    # corrupt a mid-file line: verify refuses, repair quarantines
    path = ResultStore.resolve_path(str(tmp_path / "a"))
    lines = open(path).read().splitlines(True)
    lines.insert(0, "garbage\n")
    with open(path, "w") as f:
        f.writelines(lines)
    rc = cli.main([str(tmp_path / "m2"), str(tmp_path / "a"), "--verify"])
    assert rc == 4
    assert "CORRUPT" in capsys.readouterr().err
    with pytest.warns(RuntimeWarning):
        rc = cli.main([str(tmp_path / "m3"), str(tmp_path / "a"),
                       "--verify", "--repair"])
    assert rc == 0
    assert len(ResultStore(str(tmp_path / "m3"))) == 3


# ---------------------------------------------------------------------------
# process executor: worker kills, pool rebuild, straggler deadlines
# (unmarked: real subprocess work, runs in the full suite / `make faults`)
# ---------------------------------------------------------------------------

def test_process_pool_survives_worker_kills(reference):
    # every point's first attempt SIGKILLs its worker: the pool is rebuilt,
    # unfinished points resubmitted, and the sweep still converges exactly
    inj = FaultInjector(seed=3, kill_rate=1.0, max_faults_per_point=1)
    sess = ExplorationSession(retry_policy=RetryPolicy(max_attempts=2),
                              fault_injector=inj)
    sweep = sess.run(_space(), executor="process", max_workers=2)
    assert _metric_seq(sweep.records) == _metric_seq(reference.records)
    assert sweep.n_failed == 0
    assert sweep.n_retried >= len(reference.records)


def test_process_pool_mixed_fault_schedule(reference):
    inj = FaultInjector(seed=7, exception_rate=0.4, kill_rate=0.3,
                        max_faults_per_point=2)
    sess = ExplorationSession(retry_policy=RetryPolicy(max_attempts=3),
                              fault_injector=inj)
    sweep = sess.run(_space(), executor="process", max_workers=2)
    assert _metric_seq(sweep.records) == _metric_seq(reference.records)
    assert sweep.n_failed == 0


def test_process_pool_kill_without_budget_quarantines(reference):
    inj = FaultInjector(seed=3, kill_rate=1.0)   # no gate: kills every try
    sess = ExplorationSession(fault_injector=inj)
    sweep = sess.run(_space(granularities=["layer"]),
                     executor="process", max_workers=2)
    assert len(sweep.records) == 0
    assert sweep.n_failed == 3
    assert all(f.attempts >= 1 for f in sweep.failures)


def test_deadline_redispatches_stragglers(reference):
    # every first attempt sleeps far past the deadline; the parent times
    # out, re-dispatches, and the fresh attempt (gated clean) wins
    inj = FaultInjector(seed=0, delay_rate=1.0, delay_s=20.0,
                        max_faults_per_point=1)
    sess = ExplorationSession(retry_policy=RetryPolicy(max_attempts=3),
                              fault_injector=inj, deadline_s=1.0)
    space = _space(archs={"SC:TPU": sc_tpu}, granularities=["layer"])
    t0 = time.monotonic()
    sweep = sess.run(space, executor="process", max_workers=2)
    ref = ExplorationSession().run(space)
    assert _metric_seq(sweep.records) == _metric_seq(ref.records)
    assert sweep.n_failed == 0 and sweep.n_retried >= 1
    assert time.monotonic() - t0 < 20.0    # did not wait out the straggler


# ---------------------------------------------------------------------------
# crash-restart: SIGKILL a run_shard subprocess mid-sweep, restart, merge
# ---------------------------------------------------------------------------

_DRIVER = """
import sys
from repro.api import FaultInjector, run_shard
# delay every point so the parent can reliably kill us mid-sweep
inj = FaultInjector(seed=0, delay_rate=1.0, delay_s=0.5)
run_shard(sys.argv[1], cache_dir=sys.argv[2],
          fault_injector=inj, heartbeat=sys.argv[3])
"""


def test_sigkill_crash_restart_is_bit_identical(tmp_path, reference):
    m = build_manifest(_space())
    mpath = str(tmp_path / "sweep.json")
    m.save(mpath)
    out = str(tmp_path / "shard")
    hb_path = str(tmp_path / "hb.json")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen([sys.executable, "-c", _DRIVER, mpath, out,
                             hb_path], env=env,
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    try:
        # wait until the shard is demonstrably mid-sweep (>= 1 point done),
        # then SIGKILL it — possibly mid-append, which is the point
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                break
            try:
                if json.load(open(hb_path))["done"] >= 1:
                    break
            except (FileNotFoundError, json.JSONDecodeError, KeyError):
                pass
            time.sleep(0.02)
        killed = proc.poll() is None
        if killed:
            proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert killed, "shard finished before it could be killed"
    # whatever landed before the kill is loadable (a torn tail at worst)...
    partial = ResultStore(out)
    assert 1 <= len(partial) < len(reference.records)
    # ...and the restart is incremental: done points come from the store
    sweep = run_shard(mpath, cache_dir=out)
    assert sweep.n_from_store == len(partial)
    assert sweep.n_failed == 0
    merged = ResultStore(out)
    assert _metric_set(merged.values()) == _metric_set(reference.records)
    assert merged.verify()["n_records"] >= len(reference.records)
