"""Observability subsystem (`repro.obs`): sim-time tracer semantics, trace
export byte-determinism (repeated runs, serial vs process executors),
bottleneck-report consistency against `ScheduleResult` metrics and the
analytical lower bound, bit-identity of content-keyed records under
tracing, heartbeat metric embedding, and the sweep_top fleet dashboard."""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.api import (DesignSpace, ExplorationSession, FaultInjector,
                       GAConfig, HeartbeatMonitor, build_manifest, run_shard)
from repro.configs.paper_workloads import fsrcnn
from repro.core import CostModel, build_graph
from repro.core.allocator import manual_pingpong
from repro.core.scheduler import ScheduleEngine
from repro.core.vectorized import get_batched_fitness
from repro.hw.catalog import mc_hom_tpu, mc_hom_tpu_chip4
from repro.obs import (NULL_TRACER, InMemorySink, JsonlSink, Tracer,
                       bottleneck_report, chrome_trace_json,
                       schedule_trace_events, serving_trace_events,
                       trace_schedule, validate_trace_events,
                       write_chrome_trace)
from repro.serve.arrivals import poisson_trace
from repro.serve.simulator import PhaseCosts, simulate

pytestmark = pytest.mark.tier1

GA = GAConfig(pop_size=4, generations=2)


def _space():
    return DesignSpace(workloads={"fsrcnn": fsrcnn()},
                       archs={"MC:HomTPU": mc_hom_tpu},
                       granularities=["layer", ("tile", 8, 1)], ga=GA)


def _chip4_engine():
    w, acc = fsrcnn(), mc_hom_tpu_chip4()
    graph = build_graph(w, acc, ("tile", 8, 1))
    return w, acc, ScheduleEngine(graph, CostModel(w, acc), acc)


def _load_tool(name):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(root, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# tracer / sinks
# ---------------------------------------------------------------------------

def test_tracer_nested_spans_and_metrics():
    tr = Tracer()
    with tr.span("outer", point="k0"):
        with tr.span("inner"):
            tr.count("n")
        tr.observe("v", 3.0)
        tr.observe("v", 5.0)
    assert [(e.name, e.depth) for e in tr.events] == \
        [("inner", 1), ("outer", 0)]
    assert tr.events[1].attrs == {"point": "k0"}
    assert tr.events[1].t0 < tr.events[0].t0  # outer opened first
    snap = tr.snapshot()
    assert snap["counters"] == {"n": 1.0}
    assert snap["histograms"]["v"] == {
        "count": 2, "total": 8.0, "mean": 4.0, "min": 3.0, "max": 5.0}


def test_span_closed_on_exception():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    assert [e.name for e in tr.events] == ["boom"]


def test_explicit_sim_cycle_spans():
    tr = Tracer()
    tr.add_span("segment", 0.0, 128.0, seg=0)
    ev = tr.events[0]
    assert (ev.t0, ev.t1, ev.duration, ev.attrs["seg"]) == \
        (0.0, 128.0, 128.0, 0)


def test_jsonl_sink_byte_identical(tmp_path):
    paths = [str(tmp_path / f"{i}.jsonl") for i in (0, 1)]
    for path in paths:
        tr = Tracer(sink=JsonlSink(path))
        with tr.span("a", k=1):
            pass
        tr.add_span("b", 2.0, 4.0)
        tr.close()
    blobs = [open(p, "rb").read() for p in paths]
    assert blobs[0] == blobs[1] and blobs[0]
    assert [json.loads(line)["name"]
            for line in blobs[0].decode().splitlines()] == ["a", "b"]


def test_null_tracer_is_inert():
    with NULL_TRACER.span("x", a=1):
        NULL_TRACER.count("n", 5)
        NULL_TRACER.observe("v", 1.0)
    NULL_TRACER.add_span("y", 0.0, 1.0)
    assert NULL_TRACER.events == []
    assert NULL_TRACER.snapshot() == {"counters": {}, "histograms": {}}


def test_wall_tracer_uses_wall_clock():
    # the REALTIME channel: spans carry monotonically advancing wall times
    from repro.obs.realtime import wall_clock, wall_tracer
    assert wall_clock() <= wall_clock()
    tracer = wall_tracer()
    with tracer.span("op"):
        pass
    (ev,) = tracer.events
    assert ev.name == "op" and ev.t1 >= ev.t0 >= 0.0


# ---------------------------------------------------------------------------
# trace export: schema, lanes, byte determinism
# ---------------------------------------------------------------------------

def test_schedule_trace_byte_identical_across_runs():
    blobs = []
    for _ in range(2):  # fresh engine each run: no shared state
        _, acc, engine = _chip4_engine()
        events, result = trace_schedule(engine,
                                        manual_pingpong(fsrcnn(), acc))
        assert validate_trace_events(events) == []
        blobs.append(chrome_trace_json(events))
    assert blobs[0] == blobs[1]
    assert json.loads(blobs[0])["traceEvents"]  # loadable, non-empty


def test_schedule_trace_lanes_and_segments():
    w, acc, engine = _chip4_engine()
    events, result = trace_schedule(engine, manual_pingpong(w, acc))
    lanes = {e["tid"]: e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    names = list(lanes.values())
    # one lane per core, at least one channel lane, one DRAM lane,
    # one segment-marker lane
    assert sum(n.startswith("core") for n in names) == len(acc.cores)
    assert any(n.startswith("chan") or n == "bus" for n in names)
    assert "dram" in names and "segments" in names
    seg_names = [e["name"] for e in events
                 if e["ph"] == "X" and lanes[e["tid"]] == "segments"]
    assert seg_names and all(n.startswith("segment ") for n in seg_names)
    # every compute interval landed on its core's lane
    for i, intervals in enumerate(result.core_intervals):
        lane_events = [e for e in events
                       if e["ph"] == "X" and e.get("tid") == i]
        assert len(lane_events) == len(intervals)
    # activation counters present and running totals never negative
    counters = [e for e in events if e["ph"] == "C"]
    assert counters and all(e["args"]["bytes"] >= -1e-9 for e in counters)


def test_trace_export_identical_across_executors(tmp_path):
    space = DesignSpace(workloads={"fsrcnn": fsrcnn()},
                        archs={"MC:HomTPU": mc_hom_tpu},
                        granularities=[("tile", 8, 1)], ga=GA)
    by_exec = {}
    for executor in ("serial", "process"):
        sweep = ExplorationSession().run(space, executor=executor,
                                         max_workers=2)
        assert sweep.n_failed == 0
        _, acc, engine = _chip4_engine()
        blobs = [chrome_trace_json(
            trace_schedule(engine, np.asarray(r.allocation))[0])
            for r in sweep.records]
        by_exec[executor] = blobs
    assert by_exec["serial"] == by_exec["process"]


def test_write_chrome_trace_and_validate(tmp_path):
    path = str(tmp_path / "t.json")
    write_chrome_trace([{"name": "a", "ph": "X", "pid": 0, "tid": 0,
                         "ts": 0.0, "dur": 1.0}], path)
    assert json.load(open(path))["traceEvents"][0]["name"] == "a"
    assert validate_trace_events([{"ph": "Z"}]) == \
        ["event 0: unknown ph 'Z'"]
    assert validate_trace_events(
        [{"name": "c", "ph": "C", "pid": 0, "ts": 0.0,
          "args": {"v": "nan-string"}}]) == \
        ["event 0: counter without numeric args"]


# ---------------------------------------------------------------------------
# serving trace
# ---------------------------------------------------------------------------

def test_serving_steps_and_trace():
    costs = PhaseCosts(prefill_cc=100.0, prefill_pj=2.0,
                       decode_cc=10.0, decode_pj=1.0)
    trace = poisson_trace(2000.0, 8, seed=0, decode_tokens=4)
    sim = simulate(trace, costs, batch_slots=2)
    assert len(sim.steps) == sim.n_prefill_steps + sim.n_decode_steps
    assert all(t1 > t0 and kind in ("prefill", "decode")
               and 0 < n <= sim.batch_slots
               for (t0, t1, kind, n) in sim.steps)
    events = serving_trace_events(sim)
    assert validate_trace_events(events) == []
    engine_lane = [e for e in events if e["ph"] == "X" and e["tid"] == 0]
    assert len(engine_lane) == len(sim.steps)
    # one queue-or-serve lifecycle lane per request
    serve_spans = [e for e in events
                   if e["ph"] == "X" and e["name"] == "serve"]
    assert len(serve_spans) == sim.n_requests
    occupancy = [e for e in events if e["ph"] == "C"]
    assert len(occupancy) == len(sim.steps)


def test_serving_tracer_and_bit_identity():
    costs = PhaseCosts(prefill_cc=100.0, prefill_pj=2.0,
                       decode_cc=10.0, decode_pj=1.0)
    trace = poisson_trace(1000.0, 6, seed=1, decode_tokens=3)
    plain = simulate(trace, costs, batch_slots=3)
    tr = Tracer()
    traced = simulate(trace, costs, batch_slots=3, tracer=tr)
    assert plain.to_dict() == traced.to_dict()
    counters = tr.snapshot()["counters"]
    assert counters["serving.requests"] == 6
    assert counters["serving.prefill_steps"] == plain.n_prefill_steps
    assert counters["serving.decode_steps"] == plain.n_decode_steps


# ---------------------------------------------------------------------------
# bottleneck report
# ---------------------------------------------------------------------------

def test_report_consistent_with_schedule_result():
    w, acc, engine = _chip4_engine()
    alloc = manual_pingpong(w, acc)
    result = engine.schedule(alloc, "latency")
    bf = get_batched_fitness(engine, priority="latency")
    lb = float(bf.latency_lower_bound(np.asarray(alloc)[None, :])[0])
    rep = bottleneck_report(result, lower_bound_cc=lb)
    assert rep.makespan_cc == result.latency_cc
    assert rep.energy_pj == result.energy_pj
    # busy fractions are exactly core_busy / makespan
    assert np.allclose(rep.core_busy_frac,
                       np.asarray(result.core_busy) / result.latency_cc)
    assert all(0.0 <= f <= 1.0 for f in rep.core_busy_frac)
    # floors: per-core from core_busy, dram/comm from interval sums
    for i, busy in enumerate(result.core_busy):
        assert rep.floors_cc[f"core{i}"] == float(busy)
    assert rep.dram_busy_cc == pytest.approx(
        sum(e - s for (s, e, _k, _b) in result.dram_intervals))
    # stall accounting: every floor and the analytical bound are true
    # lower bounds on the achieved makespan
    assert lb <= result.latency_cc
    assert max(rep.floors_cc.values()) <= rep.makespan_cc + 1e-9
    assert rep.bound_cc <= rep.makespan_cc + 1e-9
    assert rep.slack_cc == pytest.approx(rep.makespan_cc - rep.bound_cc)
    # renderings are consistent and deterministic
    assert json.loads(rep.to_json()) == rep.to_dict()
    assert rep.to_text() == bottleneck_report(
        result, lower_bound_cc=lb).to_text()
    assert rep.critical_resource in rep.floors_cc or \
        rep.critical_resource == "analytical"


# ---------------------------------------------------------------------------
# tracing is pure observation: bit-identity of content-keyed outputs
# ---------------------------------------------------------------------------

def _content(record) -> dict:
    d = record.to_dict()
    d.pop("runtime_s")   # operator wall timing: excluded from content keys
    return d


def test_tracing_keeps_records_bit_identical():
    plain = ExplorationSession().run(_space())
    tr = Tracer()
    traced = ExplorationSession(tracer=tr).run(_space())
    assert [_content(r) for r in plain.records] == \
        [_content(r) for r in traced.records]
    counters = tr.snapshot()["counters"]
    assert counters["sweep.computed"] == traced.n_scheduled
    assert counters["engine.schedules"] > 0
    assert counters["ga.generations"] > 0


def test_ga_generation_spans_and_store_hit_counter(tmp_path):
    tr = Tracer()
    sess = ExplorationSession(cache_dir=str(tmp_path), tracer=tr)
    sess.run(_space())
    gens = [e for e in tr.events if e.name == "ga.generation"]
    assert gens and all(e.t1 == e.t0 + 1.0 for e in gens)
    assert all(e.attrs["evaluations"] >= 0 and "best" in e.attrs
               for e in gens)
    before = tr.snapshot()["counters"].get("sweep.store_hits", 0)
    sweep2 = sess.run(_space())   # warm store: all points served from disk
    after = tr.snapshot()["counters"]["sweep.store_hits"]
    assert after - before == sweep2.n_from_store == len(sweep2.records)
    snap = sess.metrics_snapshot()
    assert snap["store_records"] == len(sweep2.records)
    assert snap["store_failures"] == 0
    assert snap["sweep.store_hits"] == after


# ---------------------------------------------------------------------------
# heartbeat metrics + quarantine-exit heartbeat
# ---------------------------------------------------------------------------

def test_heartbeat_embeds_metrics_snapshot(tmp_path):
    path = str(tmp_path / "hb.json")
    hb = HeartbeatMonitor(path, total=3,
                          metrics=lambda: {"store_records": 2,
                                           "sweep.computed": 2.0})
    hb.update_failure("boom")
    beat = json.load(open(path))
    assert beat["metrics"] == {"store_records": 2, "sweep.computed": 2.0}
    assert beat["points_per_s"] >= 0.0
    hb.finalize("done")
    assert json.load(open(path))["status"] == "done"


def test_run_shard_heartbeat_has_metrics(tmp_path):
    sweep = run_shard(build_manifest(_space()),
                      cache_dir=str(tmp_path / "store"),
                      heartbeat=str(tmp_path / "hb.json"))
    beat = json.load(open(tmp_path / "hb.json"))
    assert beat["status"] == "done"
    assert beat["metrics"]["store_records"] == len(sweep)
    assert beat["metrics"]["store_failures"] == 0
    assert "points_per_s" in beat


def test_run_shard_quarantine_exit_stamps_heartbeat(tmp_path):
    # every attempt faults, no retries: the exit-3 path must still leave
    # a terminal heartbeat naming the quarantine outcome
    sweep = run_shard(build_manifest(_space()),
                      cache_dir=str(tmp_path / "store"),
                      fault_injector=FaultInjector(seed=0,
                                                   exception_rate=1.0),
                      heartbeat=str(tmp_path / "hb.json"))
    assert len(sweep.records) == 0 and sweep.n_failed > 0
    beat = json.load(open(tmp_path / "hb.json"))
    assert beat["status"] == "quarantined"
    assert beat["failed"] == sweep.n_failed
    assert beat["metrics"]["store_failures"] == sweep.n_failed


# ---------------------------------------------------------------------------
# sweep_top dashboard
# ---------------------------------------------------------------------------

def test_sweep_top_fleet_view(tmp_path):
    top = _load_tool("sweep_top")
    beats, stores = [], []
    for k, status in enumerate(("running", "done")):
        shard = tmp_path / f"shard{k}"
        shard.mkdir()
        beat = {"status": status, "done": 3 + k, "failed": k, "total": 8,
                "shard_index": k, "n_shards": 2, "seq": 4,
                "updated_unix": 0.0, "points_per_s": 1.5,
                "metrics": {"store_records": 3 + k}}
        (shard / "heartbeat.json").write_text(json.dumps(beat))
        rows = [{"key": f"k{k}{i}", "edp": 10.0 * (k + 1) + i,
                 "latency_cc": 5.0 + i} for i in range(3)]
        (shard / "records.jsonl").write_text(
            "\n".join(json.dumps(r) for r in rows) + "\n"
            + '{"torn line')          # in-flight append: must be skipped
        beats.append(str(shard / "heartbeat.json"))
        stores.append(str(shard))
    snap = top.fleet_snapshot(beats, stores)
    t = snap["totals"]
    assert (t["done"], t["failed"], t["total"], t["live"]) == (7, 1, 16, 2)
    assert t["records"] == 6 and t["best_edp"] == 10.0
    assert t["points_per_s"] == pytest.approx(3.0)
    text = top.render(snap)
    assert "fleet: 2/2 live" in text and "done 7/16" in text
    # discovery finds the same fleet from the root directory
    d_beats, d_stores = top.discover(str(tmp_path))
    assert d_beats == sorted(beats) and d_stores == sorted(stores)
    # missing heartbeat renders as a dead shard, not a crash
    snap2 = top.fleet_snapshot(beats + [str(tmp_path / "nope.json")], stores)
    assert snap2["totals"]["live"] == 2
    assert "no beat" in top.render(snap2)
    assert top.read_heartbeat(str(tmp_path / "nope.json")) is None
    assert top.tail_store(str(tmp_path / "empty")) == {
        "records": 0, "best_edp": None, "best_latency_cc": None}


def test_trace_export_tool_is_deterministic(tmp_path):
    tool = _load_tool("trace_export")
    blobs = []
    for sub in ("a", "b"):
        paths = tool.export_all(str(tmp_path / sub))
        blobs.append({name: open(p, "rb").read()
                      for name, p in paths.items()})
    assert blobs[0] == blobs[1]
    for name in ("schedule", "serving"):
        doc = json.loads(blobs[0][name])
        assert doc["traceEvents"]
    report = json.loads(blobs[0]["report_json"])
    assert report["slack_cc"] >= 0.0
