"""Chiplet-topology model: spec round-trips, hop-table derivation, and the
degenerate-case golden contract (a single-cluster topology schedules
bit-identically to the flat single-bus `ArchSpec`)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (ArchSpec, ClusterSpec, CoreSpec, DesignSpace, GAConfig,
                       LinkSpec, TopologySpec, as_arch_spec, max_clusters,
                       partition_topology)
from repro.configs.paper_workloads import squeezenet
from repro.core import CostModel, build_graph, explore
from repro.core.allocator import manual_pingpong
from repro.core.scheduler import ScheduleEngine, schedule_reference
from repro.core.stream_api import core_symmetry_canonicalize
from repro.hw.catalog import (CHIPLET_ARCHITECTURES, mc_hetero, mc_hom_tpu,
                              simd_core, with_chiplets)
from repro.hw.topology import build_channels

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# spec serialization + content hashing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CHIPLET_ARCHITECTURES))
def test_chiplet_catalog_round_trip(name):
    acc = CHIPLET_ARCHITECTURES[name]()
    spec = ArchSpec.from_accelerator(acc)
    assert spec.to_accelerator() == acc
    assert ArchSpec.from_json(spec.to_json()) == spec
    json.loads(spec.to_json())


def test_hop_table_spec_round_trip():
    t = TopologySpec(clusters=(("a", ("x",)), ("b", ("y",))),
                     hops=((0, 3), (3, 0)))
    spec = ArchSpec(name="hops", cores=(
        CoreSpec.from_core(mc_hom_tpu().cores[0]).with_(name="x"),
        CoreSpec.from_core(mc_hom_tpu().cores[1]).with_(name="y")),
        topology=t)
    back = ArchSpec.from_json(spec.to_json())
    assert back == spec
    assert back.topology.hops == ((0, 3), (3, 0))


def test_flat_content_key_is_stable():
    """Flat specs omit the topology entry, so pre-topology content keys
    (and every stored sweep record keyed by them) remain valid."""
    spec = as_arch_spec(mc_hetero())
    assert "topology" not in spec.to_dict()
    assert spec.content_key() == "3c27e2d6bdc4c4ce"  # pre-topology value


def test_content_key_tracks_topology():
    flat = as_arch_spec(mc_hom_tpu())
    chip2 = as_arch_spec(with_chiplets(mc_hom_tpu(), 2))
    chip2b = as_arch_spec(with_chiplets(mc_hom_tpu(), 2))
    assert chip2.content_key() == chip2b.content_key()
    assert chip2.content_key() != flat.content_key()
    faster = chip2.with_(topology=dataclasses.replace(
        chip2.topology, links=tuple(
            dataclasses.replace(l, bw_bits_per_cc=l.bw_bits_per_cc * 2)
            for l in chip2.topology.links)))
    assert faster.content_key() != chip2.content_key()


# ---------------------------------------------------------------------------
# hop-table derivation (generators + explicit tables)
# ---------------------------------------------------------------------------

def test_ring_hop_derivation():
    t = TopologySpec.ring({f"c{i}": (f"core{i}",) for i in range(5)})
    assert len(t.links) == 5
    h = t.hop_table()
    assert h[0] == (0, 1, 2, 2, 1)           # wrap-around shortest paths
    assert all(h[i][j] == h[j][i] for i in range(5) for j in range(5))
    two = TopologySpec.ring({"a": ("x",), "b": ("y",)})
    assert len(two.links) == 1               # no duplicate 2-cluster ring link
    assert two.hop_table() == ((0, 1), (1, 0))
    one = TopologySpec.ring({"a": ("x",)})
    assert one.links == () and one.hop_table() == ((0,),)


def test_mesh_hop_derivation():
    t = TopologySpec.mesh({f"c{i}": (f"core{i}",) for i in range(6)}, cols=3)
    # 2x3 mesh: 7 links (4 horizontal + 3 vertical), corner-to-corner 3 hops
    assert len(t.links) == 7
    h = t.hop_table()
    assert h[0][5] == 3 and h[0][1] == 1 and h[0][3] == 1 and h[2][3] == 3


def test_explicit_hops_validation():
    mk = lambda hops: TopologySpec(
        clusters=(("a", ("x",)), ("b", ("y",))), hops=hops).validate()
    assert mk(((0, 2), (2, 0))).hop_table() == ((0, 2), (2, 0))
    with pytest.raises(ValueError, match="symmetric"):
        mk(((0, 2), (1, 0)))
    with pytest.raises(ValueError, match="diagonal"):
        mk(((1, 2), (2, 0)))
    with pytest.raises(ValueError, match="at least one hop"):
        mk(((0, 0), (0, 0)))
    with pytest.raises(ValueError, match="2x2"):
        mk(((0,),))


def test_topology_validation_against_cores():
    acc = mc_hom_tpu()
    with pytest.raises(ValueError, match="has cores"):
        dataclasses.replace(acc, topology=TopologySpec.ring(
            {"a": ("tpu0", "tpu1")}))       # misses tpu2/tpu3/simd
    with pytest.raises(ValueError, match="more than one cluster"):
        TopologySpec.ring({"a": ("x",), "b": ("x",)}).validate()
    with pytest.raises(ValueError, match="unreachable"):
        TopologySpec(clusters=(("a", ("x",)), ("b", ("y",)))).validate()
    with pytest.raises(ValueError, match="shared_mem"):
        from repro.hw.catalog import diana
        d = diana()
        dataclasses.replace(d, topology=TopologySpec.ring(
            {"all": tuple(c.name for c in d.cores)}))


def test_partition_topology():
    t = partition_topology(mc_hom_tpu(), 2)
    assert [c.cores for c in t.clusters] == \
        [("tpu0", "tpu1", "simd"), ("tpu2", "tpu3")]
    with pytest.raises(ValueError, match="equal chiplets"):
        partition_topology(mc_hom_tpu(), 3)
    with pytest.raises(ValueError, match="generator"):
        partition_topology(mc_hom_tpu(), 2, generator="torus")


def test_grid_explicit_topology_entries():
    """Explicit TopologySpec axis entries attach only to grid points whose
    core names they cover, and distinct topologies with equal cluster
    counts get distinct names (axis-position labels)."""
    tpu = CoreSpec.from_core(mc_hetero().cores[2])
    names4 = [f"tpu0{i}" for i in range(4)]
    ring = TopologySpec.ring({f"r{k}": (names4[k],) for k in range(4)})
    mesh = TopologySpec.mesh({f"m{k}": (names4[k],) for k in range(4)}, cols=2)
    grid = ArchSpec.grid(tpu, cores=[2, 4], chiplets=[ring, mesh])
    # the 2-core points are skipped (topologies name tpu00..tpu03)
    assert [g.n_cores for g in grid] == [4, 4]
    assert len({g.name for g in grid}) == 2
    assert len({g.content_key() for g in grid}) == 2
    for g in grid:
        g.to_accelerator()              # validates cluster/core coverage


def test_grid_chiplet_axis():
    tpu = CoreSpec.from_core(mc_hetero().cores[2])
    grid = ArchSpec.grid(tpu, cores=[2, 4], chiplets=[None, 2, 4],
                         simd=simd_core())
    # 2 cores x {flat, chip2} + 4 cores x {flat, chip2, chip4}: chip4 of a
    # 2-core point does not divide and is skipped
    assert len(grid) == 5
    assert len({g.name for g in grid}) == 5
    assert len({g.content_key() for g in grid}) == 5
    by_name = {g.name: g for g in grid}
    chip2 = by_name["tpu0x4-a112w128-chip2"]
    assert chip2.n_clusters == 2
    assert chip2.topology.clusters[0].cores == ("tpu00", "tpu01", "simd")
    chip2.to_accelerator()                  # validates cluster/core names


# ---------------------------------------------------------------------------
# scheduling semantics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sqz_setup():
    w = squeezenet()
    flat = mc_hom_tpu()
    graph = build_graph(w, flat, ("tile", 16, 1))
    alloc = manual_pingpong(w, flat)
    return w, flat, graph, alloc


def _engine(w, graph, acc):
    return ScheduleEngine(graph, CostModel(w, acc), acc)


def _assert_identical(a, b, chan=True):
    assert a.latency_cc == b.latency_cc
    assert a.energy_pj == b.energy_pj
    assert a.energy_breakdown == b.energy_breakdown
    assert a.peak_mem_bytes == b.peak_mem_bytes
    assert a.act_peak_bytes == b.act_peak_bytes
    assert a.mem_events == b.mem_events
    assert a.comm_intervals == b.comm_intervals
    assert a.dram_intervals == b.dram_intervals
    if chan:
        assert a.chan_intervals == b.chan_intervals
    assert np.array_equal(a.core_busy, b.core_busy)


@pytest.mark.parametrize("priority", ["latency", "memory"])
def test_single_cluster_degenerates_to_flat(sqz_setup, priority):
    """The golden degenerate case: one cluster, zero hops == the flat
    shared-bus model, bit for bit (the single-cluster route is priced
    through the channel path, not special-cased away)."""
    w, flat, graph, alloc = sqz_setup
    chip1 = with_chiplets(flat, 1)
    e1 = _engine(w, graph, chip1)
    assert e1._routes is not None           # channel path exercised
    for mode in ({}, {"segment": False}, {"strict_layers": True}):
        r_flat = _engine(w, graph, flat).schedule(alloc, priority, **mode)
        r_chip = e1.schedule(alloc, priority, **mode)
        # the flat arch has no channels; chip1's one local bus must carry
        # exactly the flat bus's transfer envelopes on channel 0
        _assert_identical(r_flat, r_chip, chan=False)
        assert r_chip.chan_intervals == \
            [(s, e, 0, b) for s, e, _u, _v, b in r_flat.comm_intervals]


def test_single_cluster_explore_matches_flat():
    """End-to-end GA exploration on the degenerate topology reproduces the
    flat result exactly (same trajectory, same allocation, same metrics)."""
    w = squeezenet()
    flat = mc_hom_tpu()
    r_flat = explore(w, flat, granularity=("tile", 16, 1),
                     pop_size=6, generations=3)
    r_chip1 = explore(w, with_chiplets(flat, 1), granularity=("tile", 16, 1),
                      pop_size=6, generations=3)
    assert r_chip1.latency_cc == r_flat.latency_cc
    assert r_chip1.energy_pj == r_flat.energy_pj
    assert np.array_equal(r_chip1.allocation, r_flat.allocation)


@pytest.mark.parametrize("priority", ["latency", "memory"])
def test_engine_matches_reference_on_chiplets(sqz_setup, priority):
    w, flat, graph, alloc = sqz_setup
    for acc in (with_chiplets(flat, 2), with_chiplets(flat, 4),
                with_chiplets(mc_hetero(), 2)):
        a = manual_pingpong(w, acc)
        got = _engine(w, graph, acc).schedule(a, priority)
        ref = schedule_reference(graph, CostModel(w, acc), a, acc, priority)
        _assert_identical(got, ref)


@pytest.mark.parametrize("priority", ["latency", "memory"])
@pytest.mark.parametrize("chiplets", [1, 2, 4])
def test_topology_traces_validate_clean(sqz_setup, priority, chiplets):
    """The race detector passes on engine and reference traces for every
    chiplet count: per-channel FCFS never double-books a link, and the
    multi-hop envelopes respect dependency order."""
    from repro.analysis.staticcheck import validate_trace
    w, flat, graph, alloc = sqz_setup
    acc = with_chiplets(flat, chiplets)
    engine = _engine(w, graph, acc)
    got = engine.schedule(alloc, priority, validate=True)  # raises on races
    ref = schedule_reference(graph, CostModel(w, acc), alloc, acc, priority)
    report = validate_trace(ref, graph, acc, workload=w)
    assert report["cns"] == graph.n
    if chiplets > 1:
        # chiplets -> per-cluster buses + ring links, all hops recorded
        assert report["channels"] > 1 and got.chan_intervals


def test_checkpoint_resume_on_chiplets(sqz_setup):
    """Segment-checkpoint resumes stay bit-identical with channel state."""
    w, flat, graph, alloc = sqz_setup
    acc = with_chiplets(flat, 2)
    engine = _engine(w, graph, acc)
    cold = engine.evaluate(alloc, "latency")
    mutated = np.array(alloc)
    mutated[-1] = (mutated[-1] + 1) % 4
    engine.evaluate(mutated, "latency")
    warm = engine.evaluate(alloc, "latency")
    assert engine.ckpt_stats["resume_hits"] > 0
    assert warm == cold


def test_multi_hop_pricing(sqz_setup):
    """hops=2 prices a transfer at twice the link energy and no less
    latency than hops=1; more clusters never cheapen the interconnect."""
    w, flat, graph, alloc = sqz_setup

    def hops_arch(h):
        topo = TopologySpec(
            clusters=(("a", ("tpu0", "tpu1", "simd")), ("b", ("tpu2", "tpu3"))),
            hops=((0, h), (h, 0)))
        return dataclasses.replace(flat, name=f"hops{h}", topology=topo)

    r1 = _engine(w, graph, hops_arch(1)).schedule(alloc)
    r2 = _engine(w, graph, hops_arch(2)).schedule(alloc)
    flat_res = _engine(w, graph, flat).schedule(alloc)
    # inter-cluster bytes pay per hop: bus energy above the intra-cluster
    # share (the flat-local part of r1) exactly doubles
    intra = 2 * r1.energy_breakdown["bus"] - r2.energy_breakdown["bus"]
    assert r2.energy_breakdown["bus"] > r1.energy_breakdown["bus"] > \
        flat_res.energy_breakdown["bus"] * 0.99
    assert intra >= -1e-6
    assert r2.latency_cc >= r1.latency_cc


def test_link_contention_serializes(sqz_setup):
    """Halving link bandwidth cannot reduce latency and strictly stretches
    the busiest transfer windows (FCFS per link)."""
    w, flat, graph, alloc = sqz_setup
    fast = with_chiplets(flat, 2, link_bw_bits_per_cc=128.0)
    slow = with_chiplets(flat, 2, link_bw_bits_per_cc=16.0)
    r_fast = _engine(w, graph, fast).schedule(alloc)
    r_slow = _engine(w, graph, slow).schedule(alloc)
    assert r_slow.latency_cc >= r_fast.latency_cc
    dur = lambda r: sum(e - s for s, e, *_ in r.comm_intervals)
    assert dur(r_slow) > dur(r_fast)


def test_build_channels_routes():
    acc = with_chiplets(mc_hom_tpu(), 2)
    chan_bw, chan_e, routes = build_channels(acc)
    # 2 local buses + 1 ring link
    assert len(chan_bw) == 3
    assert chan_bw[:2] == [acc.bus_bw_bits_per_cc] * 2
    names = [c.name for c in acc.cores]
    i = {n: k for k, n in enumerate(names)}
    assert routes[i["tpu0"]][i["tpu1"]] == (0,)      # intra-cluster: local bus
    assert routes[i["tpu2"]][i["tpu3"]] == (1,)
    assert routes[i["tpu0"]][i["tpu2"]] == (2,)      # cross-die: the link
    assert routes[i["tpu2"]][i["simd"]] == (2,)


def test_symmetry_respects_clusters():
    """Content-equal cores on different chiplets are not interchangeable:
    canonicalization may only permute within a cluster."""
    flat = mc_hom_tpu()
    canon_flat = core_symmetry_canonicalize(flat)
    assert np.array_equal(canon_flat([3, 2, 1]), [0, 1, 2])
    chip2 = with_chiplets(flat, 2)
    canon = core_symmetry_canonicalize(chip2)
    # cluster {0,1} and {2,3}: 3 maps to 2 (its cluster's first slot), 1 to 0
    assert np.array_equal(canon([3, 2, 1]), [2, 3, 0])
    assert np.array_equal(canon([1, 1, 3]), [0, 0, 2])
    # fully split: every core is its own cluster, no symmetry at all
    assert core_symmetry_canonicalize(with_chiplets(flat, 4)) is None


def test_design_space_topology_axis_and_constraint():
    flat = mc_hom_tpu()
    space = DesignSpace(
        workloads=["squeezenet"],
        archs={"flat": flat, "chip2": with_chiplets(flat, 2),
               "chip4": with_chiplets(flat, 4)},
        granularities=[("tile", 32, 1)],
        ga=GAConfig(pop_size=4, generations=2),
        constraints=[max_clusters(2)])
    assert [p.arch.name for p in space] == ["flat", "chip2"]
    keys = {p.content_key() for p in space}
    assert len(keys) == 2
    # topology survives the point's spec dict (store round trip)
    p = [p for p in space if p.arch.name == "chip2"][0]
    restored = ArchSpec.from_dict(p.spec_dict()["arch"])
    assert restored == p.arch
