"""Stream->TPU planner: pipeline planning sanity + the paper's scheduling
trade-offs reappearing at pod scale."""
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.core.planner import (contiguous_allocation, evaluate_pipeline,
                                plan)


def test_single_stage_near_ideal_utilization():
    cfg = ARCHS["deepseek-67b"]
    p = evaluate_pipeline(cfg, SHAPES["train_4k"], n_stages=1,
                          chips_per_stage=256, n_microbatches=8)
    util = p.schedule.utilization()[0]
    assert util > 0.8  # one fused stage: almost no idle time
    # step time within 2x of the analytic compute bound
    from repro.models.zoo import active_params
    ideal = 6 * active_params(cfg) * 4096 * 256 / (256 * 197e12)
    assert p.est_step_s < 2.0 * ideal


def test_memory_priority_lowers_peak_at_latency_cost():
    """Paper Fig. 7 at pod scale: 1F1B-ish (memory) vs eager (latency)."""
    cfg = ARCHS["deepseek-67b"]
    lat = evaluate_pipeline(cfg, SHAPES["train_4k"], n_stages=4,
                            chips_per_stage=64, n_microbatches=16,
                            priority="latency")
    mem = evaluate_pipeline(cfg, SHAPES["train_4k"], n_stages=4,
                            chips_per_stage=64, n_microbatches=16,
                            priority="memory")
    assert mem.est_peak_bytes < lat.est_peak_bytes
    assert lat.est_step_s < mem.est_step_s


def test_more_microbatches_shrink_bubble():
    cfg = ARCHS["deepseek-67b"]
    p4 = evaluate_pipeline(cfg, SHAPES["train_4k"], n_stages=4,
                           chips_per_stage=64, n_microbatches=4)
    p32 = evaluate_pipeline(cfg, SHAPES["train_4k"], n_stages=4,
                            chips_per_stage=64, n_microbatches=32)
    assert p32.est_step_s < p4.est_step_s


def test_contiguous_allocation_shape():
    a = contiguous_allocation(8, 4, include_bwd=True)
    assert a.shape == (16,)
    assert list(a[:8]) == [0, 0, 1, 1, 2, 2, 3, 3]
    assert list(a[8:]) == [3, 3, 2, 2, 1, 1, 0, 0]  # bwd mirrors fwd


def test_plan_search_returns_feasible():
    cfg = ARCHS["llama3.2-3b"]
    p = plan(cfg, SHAPES["train_4k"], total_chips=256,
             stage_options=(1, 4), micro_options=(8,))
    assert p.n_stages * p.chips_per_stage == 256
    assert p.est_step_s > 0
