"""Population-batched GA + segment-checkpointed incremental rescheduling.

Covers the equivalence and determinism contract of the batched hot path:
  * checkpoint-resumed schedules are bit-identical to cold engine runs and
    to the `schedule_reference` golden oracle, across both priorities and a
    single-core + heterogeneous architecture;
  * `evaluate_population` matches per-genome `evaluate`;
  * the vectorized `GeneticAllocator` reproduces identical `GAResult`
    history for a fixed seed (and, with dedup off, the legacy scalar
    trajectory recorded before vectorization);
  * union dedup removes clone rows before NSGA-II selection;
  * store-backed warm starts seed the GA from neighboring points' best
    allocations and fall back to cold starts on an empty store.
"""
import numpy as np
import pytest

from repro.api import DesignSpace, ExplorationSession, GAConfig
from repro.configs.paper_workloads import fsrcnn, resnet18, tiny_yolo
from repro.core import CostModel, build_graph
from repro.core.allocator import feasible_cores_per_layer, manual_pingpong
from repro.core.ga import GeneticAllocator
from repro.core.scheduler import ScheduleEngine, schedule_reference
from repro.core.stream_api import core_symmetry_canonicalize, \
    evaluate_allocations
from repro.hw.catalog import mc_hetero, mc_hom_tpu, sc_tpu

pytestmark = pytest.mark.tier1

SETUPS = {
    "r18-hetero": (resnet18, mc_hetero, ("tile", 16, 1)),
    "yolo-single-core": (tiny_yolo, sc_tpu, ("tile", 16, 1)),
}


@pytest.fixture(scope="module", params=sorted(SETUPS))
def setup(request):
    wl_fn, acc_fn, gran = SETUPS[request.param]
    w, acc = wl_fn(), acc_fn()
    graph = build_graph(w, acc, gran)
    cm = CostModel(w, acc)
    return w, acc, graph, cm, ScheduleEngine(graph, cm, acc)


def _mutation_stream(w, acc, n=12, seed=0):
    feas = feasible_cores_per_layer(w, acc)
    rng = np.random.default_rng(seed)
    pool = [manual_pingpong(w, acc)]
    for _ in range(n):
        a = pool[rng.integers(len(pool))].copy()
        i = rng.integers(len(a))
        a[i] = feas[i][rng.integers(len(feas[i]))]
        pool.append(a)
    return pool


@pytest.mark.parametrize("priority", ["latency", "memory"])
@pytest.mark.parametrize("mode", ["segmented", "strict_layers"])
def test_checkpoint_resume_matches_reference_and_cold(setup, priority, mode):
    w, acc, graph, cm, engine = setup
    kw = {} if mode == "segmented" else {"strict_layers": True}
    engine.reset_checkpoints()
    for alloc in _mutation_stream(w, acc):
        inc = engine.evaluate(alloc, priority, checkpoint=True, **kw)
        cold = engine.evaluate(alloc, priority, checkpoint=False, **kw)
        ref = schedule_reference(graph, cm, alloc, acc, priority, **kw)
        assert inc == cold == (ref.latency_cc, ref.energy_pj)
    if acc.n_cores > 1 and mode == "segmented":
        assert engine.ckpt_stats["snapshots"] > 0


def test_resumed_schedule_is_bit_identical_not_approximate(setup):
    """Same allocation evaluated again resumes from its deepest snapshot
    and must return the exact same floats."""
    w, acc, graph, cm, engine = setup
    alloc = manual_pingpong(w, acc)
    engine.reset_checkpoints()
    first = engine.evaluate(alloc, checkpoint=True)
    again = engine.evaluate(alloc, checkpoint=True)
    assert first == again


def test_evaluate_population_matches_scalar(setup):
    w, acc, graph, cm, engine = setup
    genomes = np.stack(_mutation_stream(w, acc, n=6, seed=3))
    batched = engine.evaluate_population(genomes, "latency")
    for row, g in zip(batched, genomes):
        assert tuple(row) == engine.evaluate(g, "latency")


def test_evaluate_allocations_api(setup):
    w, acc, graph, cm, engine = setup
    genomes = np.stack(_mutation_stream(w, acc, n=3, seed=7))
    out = evaluate_allocations(w, acc, genomes, granularity=("tile", 16, 1))
    assert out.shape == (len(genomes), 2)
    assert np.all(out > 0)


def test_canonical_form_is_fitness_preserving_and_prefix_stable():
    w, acc = resnet18(), mc_hom_tpu()
    canon = core_symmetry_canonicalize(acc)
    assert canon is not None  # 4 equal digital cores differ only by name
    graph = build_graph(w, acc, ("tile", 16, 1))
    engine = ScheduleEngine(graph, CostModel(w, acc), acc)
    for alloc in _mutation_stream(w, acc, n=4, seed=5):
        c = canon(alloc)
        assert engine.evaluate(alloc, checkpoint=False) == \
            engine.evaluate(c, checkpoint=False)
        # prefix-stability: canonical form of a prefix == prefix of the form
        k = len(alloc) // 2
        assert np.array_equal(canon(alloc[:k]), c[:k])


# ---------------------------------------------------------------------------
# vectorized GA
# ---------------------------------------------------------------------------

def _toy_eval():
    target = np.array([0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2])

    def evaluate(g):
        return (float(np.sum(g != target)) + 1.0, float(np.sum(g)) + 1.0)

    return evaluate


def test_ga_identical_history_for_fixed_seed():
    feas = [[0, 1, 2]] * 12
    results = [GeneticAllocator(12, feas, _toy_eval(), pop_size=10,
                                generations=12, seed=11).run()
               for _ in range(2)]
    a, b = results
    assert a.history == b.history
    assert np.array_equal(a.best_genome, b.best_genome)
    assert np.array_equal(a.pareto_genomes, b.pareto_genomes)
    assert a.evaluations == b.evaluations


def _legacy_scalar_ga(feas, evaluate, pop_size, generations, seed):
    """Minimal re-statement of the pre-vectorization GeneticAllocator.run
    (scalar genomes, no dedup) used as the trajectory oracle."""
    import numpy as _np

    rng = _np.random.default_rng(seed)
    feasible = [_np.asarray(f) for f in feas]
    cache, evals = {}, [0]

    def ev(g):
        k = g.tobytes()
        if k not in cache:
            cache[k] = tuple(float(x) for x in evaluate(g))
            evals[0] += 1
        return cache[k]

    def rand_g():
        return _np.array([f[rng.integers(f.size)] for f in feasible])

    def mutate(g):
        g = g.copy()
        if rng.random() < 0.5 or len(g) < 2:
            i = int(rng.integers(len(g)))
            opts = feasible[i]
            if opts.size > 1:
                choices = opts[opts != g[i]]
                g[i] = choices[rng.integers(choices.size)]
        else:
            i, j = rng.integers(0, len(g), size=2)
            if g[j] in feasible[i] and g[i] in feasible[j]:
                g[i], g[j] = g[j], g[i]
        return g

    from repro.core.ga import crowding_distance, fast_nondominated_sort
    scalarize = lambda o: float(_np.prod(o))  # noqa: E731
    pop = []
    while len(pop) < pop_size:
        pop.append(rand_g())
    objs = _np.array([ev(g) for g in pop])
    history, stale = [], 0
    for _ in range(generations):
        scal = [scalarize(o) for o in objs]
        offspring = []
        while len(offspring) < pop_size:
            i, j = rng.integers(0, len(pop), size=2)
            child = (pop[i] if scal[i] <= scal[j] else pop[j]).copy()
            if rng.random() < 0.3:
                mate = pop[int(rng.integers(len(pop)))]
                a, b = sorted(rng.integers(0, len(child), size=2))
                c2 = child.copy()
                c2[a:b + 1] = mate[a:b + 1]
                child = c2
            if rng.random() < 0.7:
                child = mutate(child)
            offspring.append(child)
        union = pop + offspring
        uobjs = _np.array([ev(g) for g in union])
        fronts = fast_nondominated_sort(uobjs)
        survivors = []
        for front in fronts:
            if len(survivors) + front.size <= pop_size:
                survivors.extend(front.tolist())
            else:
                cd = crowding_distance(uobjs[front])
                order = front[_np.argsort(-cd, kind="stable")]
                survivors.extend(order[: pop_size - len(survivors)].tolist())
                break
        pop = [union[i] for i in survivors]
        objs = uobjs[survivors]
        best = min(scalarize(o) for o in objs)
        if history and best >= history[-1] - 1e-12:
            stale += 1
        else:
            stale = 0
        history.append(best)
        if stale >= 8:
            break
    return history, evals[0]


def test_ga_matches_legacy_scalar_trajectory():
    feas = [[0, 1, 2]] * 12
    for seed in (0, 11):
        legacy_history, legacy_evals = _legacy_scalar_ga(
            feas, _toy_eval(), pop_size=10, generations=12, seed=seed)
        res = GeneticAllocator(12, feas, _toy_eval(), pop_size=10,
                               generations=12, seed=seed, dedup=False).run()
        assert res.history == legacy_history
        assert res.evaluations == legacy_evals


def test_ga_dedup_removes_clone_rows():
    """With mutation off and crossover rare, offspring are mostly clones of
    their parents; dedup must keep the fronts clone-free."""
    evaluate = lambda g: (float(np.sum(g)) + 1.0,  # noqa: E731
                          float(np.sum(g == 0)) + 1.0)
    ga = GeneticAllocator(6, [[0, 1]] * 6, evaluate, pop_size=8,
                          generations=6, seed=2, crossover_p=0.05,
                          mutation_p=0.0, dedup=True)
    res = ga.run()
    keys = {row.tobytes() for row in res.pareto_genomes}
    assert len(keys) == len(res.pareto_genomes)


def test_ga_batched_evaluator_sees_only_cache_misses():
    calls = []

    def eval_pop(genomes):
        calls.append(len(genomes))
        return np.array([(float(np.sum(g)) + 1.0, 1.0) for g in genomes])

    ga = GeneticAllocator(8, [[0, 1]] * 8, evaluate_population=eval_pop,
                          pop_size=8, generations=4, seed=0)
    res = ga.run()
    assert sum(calls) == res.evaluations       # only unique rows evaluated
    assert res.queries > res.evaluations       # clones served by the memo
    assert res.cache_hits == res.queries - res.evaluations


# ---------------------------------------------------------------------------
# store-backed warm starts
# ---------------------------------------------------------------------------

def _tiny_space(session, ga=None):
    return DesignSpace(
        workloads={"fsrcnn": fsrcnn()},
        archs={"MC:HomTPU": mc_hom_tpu()},
        granularities=[("tile", 8, 1)],
        ga=ga or GAConfig(pop_size=6, generations=2, seed=0),
    )


def test_warm_start_allocations_empty_store_falls_back():
    session = ExplorationSession()
    point = next(iter(_tiny_space(session)))
    assert session.warm_start_allocations(point) == []


def test_warm_start_allocations_from_neighbor_arch():
    session = ExplorationSession()
    w = fsrcnn()
    space = DesignSpace(workloads={"fsrcnn": w},
                        archs={"MC:HomTPU": mc_hom_tpu()},
                        granularities=[("tile", 8, 1)],
                        ga=GAConfig(pop_size=6, generations=2, seed=0))
    session.run(space)
    # a *different* arch for the same workload: the stored neighbor's best
    # allocation must seed it (feasible: both are 4 digital cores + simd)
    other = DesignSpace(workloads={"fsrcnn": w},
                        archs={"MC:Hetero": mc_hetero()},
                        granularities=[("tile", 8, 1)],
                        ga=GAConfig(pop_size=6, generations=2, seed=0))
    point = next(iter(other))
    warm = session.warm_start_allocations(point)
    stored = session.store.values()[0]
    assert any(tuple(int(x) for x in a) == stored.allocation for a in warm)
    # the identical point is a store hit, never a warm start
    same_point = next(iter(space))
    assert session.warm_start_allocations(same_point) == []


def test_warm_started_sweep_records_the_seeding():
    session = ExplorationSession(warm_start=True)
    w = resnet18()
    a1 = DesignSpace(workloads={"resnet18": w}, archs={"MC:HomTPU": mc_hom_tpu()},
                     granularities=[("tile", 8, 1)],
                     ga=GAConfig(pop_size=6, generations=2, seed=0))
    r1 = session.run(a1)
    assert r1.records[0].ga_warm_starts == 0          # store was empty
    a2 = DesignSpace(workloads={"resnet18": w}, archs={"MC:Hetero": mc_hetero()},
                     granularities=[("tile", 8, 1)],
                     ga=GAConfig(pop_size=6, generations=2, seed=0))
    r2 = session.run(a2)
    assert r2.records[0].ga_warm_starts >= 1          # seeded from neighbor
    # warm starts never break determinism bookkeeping: re-running the same
    # space is a pure store hit
    again = session.run(a2)
    assert again.n_from_store == 1 and again.n_scheduled == 0


def test_checkpoint_store_shared_across_session_explorations():
    session = ExplorationSession()
    w, acc = resnet18(), mc_hom_tpu()
    engine = session.engine(w, acc, ("tile", 16, 1))
    engine.reset_checkpoints()
    session.explore(w, acc, granularity=("tile", 16, 1),
                    pop_size=6, generations=2, seed=0)
    snaps_after_first = engine.ckpt_stats["snapshots"]
    assert snaps_after_first > 0
    session.explore(w, acc, granularity=("tile", 16, 1),
                    pop_size=6, generations=2, seed=1)
    # second exploration reuses the same engine and store
    assert session.engine(w, acc, ("tile", 16, 1)) is engine
    assert engine.ckpt_stats["resume_hits"] > 0
