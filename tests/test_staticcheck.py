"""Static-analysis layer: the determinism linter against its fixture
corpus (exact rule IDs and line numbers), the module-tier map, the pragma
machinery, the repo-lints-clean gate, and the schedule race detector —
clean on real traces, and failing with the *named* invariant when a trace
is deliberately corrupted."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.staticcheck import (DETERMINISTIC, REALTIME, RULES,
                                        TraceValidationError, lint_paths,
                                        lint_source, rule_applies,
                                        tier_of_module, tier_of_path,
                                        validate_trace)
from repro.configs.paper_workloads import fsrcnn
from repro.core import CostModel, build_graph
from repro.core.allocator import manual_pingpong
from repro.core.scheduler import ScheduleEngine
from repro.hw.catalog import mc_hom_tpu

pytestmark = pytest.mark.tier1

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "staticcheck_fixtures"


def _findings(name: str):
    """(rule, line) pairs of unallowed findings in one fixture file."""
    vs = lint_paths([str(FIXTURES / name)], tier=DETERMINISTIC)
    return [(v.rule, v.line) for v in vs if not v.allowed]


# ---------------------------------------------------------------------------
# linter: fixture corpus, exact rules + lines
# ---------------------------------------------------------------------------

def test_wall_clock_fixture():
    assert _findings("bad_wall_clock.py") == [
        ("wall-clock", 8), ("wall-clock", 9),
        ("wall-clock", 10), ("wall-clock", 11)]


def test_unseeded_rng_fixture():
    assert _findings("bad_unseeded_rng.py") == [
        ("unseeded-rng", 10), ("unseeded-rng", 11), ("unseeded-rng", 12),
        ("unseeded-rng", 13), ("unseeded-rng", 14)]


def test_wallclock_arrival_sampler_fixture():
    """The serving-contract violation: arrival gaps seeded from the wall
    clock / process RNG instead of `repro.serve.arrivals`' pure hashes."""
    assert _findings("bad_wallclock_arrivals.py") == [
        ("wall-clock", 13), ("unseeded-rng", 14), ("wall-clock", 19)]


def test_wallclock_span_fixture():
    """The two-channel observability contract: wall-clock reads inside a
    sim-time tracer span (or stamping sim-time events with wall time) are
    flagged; only `repro.obs.realtime` may bind the wall clock."""
    assert _findings("bad_wallclock_span.py") == [
        ("wall-clock", 16), ("wall-clock", 17), ("wall-clock", 18)]


def test_obs_tier_pins():
    """`repro.obs` is pinned deterministic with the single REALTIME
    carve-out for the wall-time sink."""
    assert tier_of_module("repro.obs.tracing") == DETERMINISTIC
    assert tier_of_module("repro.obs.export") == DETERMINISTIC
    assert tier_of_module("repro.obs.realtime") == REALTIME


def test_id_hash_fixture():
    assert _findings("bad_id_hash.py") == [("id-hash", 6), ("id-hash", 10)]


def test_iter_order_fixture():
    assert _findings("bad_iter_order.py") == [
        ("iter-order", 9), ("iter-order", 11), ("iter-order", 12)]


def test_submit_fixture():
    assert _findings("bad_submit_lambda.py") == [
        ("unpicklable-submit", 9), ("unpicklable-submit", 12),
        ("unpicklable-submit", 14)]


def test_good_pragmas_fixture():
    """Every intentional site is suppressed — but stays visible as allowed."""
    assert _findings("good_pragmas.py") == []
    vs = lint_paths([str(FIXTURES / "good_pragmas.py")], tier=DETERMINISTIC)
    assert [(v.rule, v.line, v.allowed) for v in vs] == [
        ("wall-clock", 7, True), ("wall-clock", 13, True)]


def test_bad_pragma_fixture():
    """A malformed pragma is itself a violation and suppresses nothing."""
    assert _findings("bad_pragma.py") == [
        ("bad-pragma", 6), ("wall-clock", 6),
        ("bad-pragma", 10), ("wall-clock", 10)]


def test_pragma_in_docstring_is_not_a_pragma():
    src = '"""uses # staticcheck: allow(wall-clock) in prose"""\n' \
          "import time\nt = time.time()\n"
    vs = lint_source(src, tier=DETERMINISTIC)
    assert [(v.rule, v.allowed) for v in vs] == [("wall-clock", False)]


def test_parse_error_is_reported():
    assert [v.rule for v in lint_source("def broken(:\n")] == ["parse-error"]


# ---------------------------------------------------------------------------
# tier map
# ---------------------------------------------------------------------------

def test_tier_map():
    assert tier_of_module("repro.core.scheduler") == DETERMINISTIC
    assert tier_of_module("repro.api.session") == DETERMINISTIC
    assert tier_of_module("repro.launch.serve") == REALTIME
    assert tier_of_path("src/repro/hw/topology.py") == DETERMINISTIC
    assert tier_of_path("benchmarks/run.py") == REALTIME
    # wall-clock is tier-scoped; RNG hygiene applies everywhere
    assert not rule_applies("wall-clock", REALTIME)
    assert rule_applies("unseeded-rng", REALTIME)
    src = "import time\nt = time.time()\nimport random\nr = random.random()\n"
    assert [v.rule for v in lint_source(src, tier=REALTIME)] \
        == ["unseeded-rng"]


def test_repo_lints_clean():
    """The merge gate: src/repro has zero unallowed violations, and every
    suppression names a known rule."""
    vs = lint_paths([str(ROOT / "src" / "repro")])
    assert [v.format() for v in vs if not v.allowed] == []
    assert all(v.rule in RULES for v in vs)
    assert any(v.allowed for v in vs)  # the audited wall-clock/id-hash sites


# ---------------------------------------------------------------------------
# CLI (`make lint`)
# ---------------------------------------------------------------------------

def _cli(*args):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_static.py"), *args],
        capture_output=True, text=True, cwd=ROOT)


def test_cli_strict_clean_repo_exits_zero():
    proc = _cli("--strict")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 violations" in proc.stdout


def test_cli_strict_exits_5_on_violations():
    # RNG hygiene applies on every tier, so the fixture trips the CLI too
    proc = _cli("--strict", str(FIXTURES / "bad_unseeded_rng.py"))
    assert proc.returncode == 5
    assert "unseeded-rng" in proc.stdout


def test_cli_json_format():
    proc = _cli("--format", "json", str(FIXTURES / "bad_unseeded_rng.py"))
    report = json.loads(proc.stdout)
    assert report["summary"]["unallowed"] == 5
    assert {v["rule"] for v in report["violations"]} == {"unseeded-rng"}
    assert all(v["line"] for v in report["violations"])


# ---------------------------------------------------------------------------
# race detector: clean traces, then one corruption per invariant
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sched():
    w, acc = fsrcnn(), mc_hom_tpu()
    graph = build_graph(w, acc, ("tile", 8, 1))
    engine = ScheduleEngine(graph, CostModel(w, acc), acc)
    alloc = manual_pingpong(w, acc)
    return w, acc, graph, engine, alloc


def test_validate_param_smoke(sched):
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency", validate=True)
    assert res.latency_cc > 0
    with pytest.raises(ValueError, match="record=True"):
        engine.schedule(alloc, "latency", record=False, validate=True)


def test_unrecorded_trace_is_rejected(sched):
    w, acc, graph, engine, alloc = sched
    lite = engine.schedule(alloc, "latency", record=False)
    with pytest.raises(ValueError, match="record=True"):
        validate_trace(lite, graph, acc, workload=w)


def test_corrupt_core_overlap_named(sched):
    """Overlapping core occupancy fails as core-exclusivity, by name."""
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency", segment=False)
    core, ivs = next((c, iv) for c, iv in enumerate(res.core_intervals)
                     if len(iv) >= 2)
    (s0, e0, i0), (s1, e1, i1) = ivs[0], ivs[1]
    ivs[1] = ((s0 + e0) / 2, e1, i1)       # starts inside CN i0's window
    with pytest.raises(TraceValidationError, match=r"\[core-exclusivity\]") \
            as exc:
        validate_trace(res, graph, acc, workload=w, segment=False)
    assert exc.value.invariant == "core-exclusivity"
    assert f"core {core}" in str(exc.value)


def test_corrupt_reordered_dependency_named(sched):
    """A transfer landing after its consumer started fails as
    dependency-order, by name."""
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency", segment=False)
    assert res.comm_intervals          # pingpong on a bus arch must transfer
    start = {}
    for ivs in res.core_intervals:
        for s, e, i in ivs:
            start[i] = s
    k, (s, e, u, v, b) = next(
        (k, iv) for k, iv in enumerate(res.comm_intervals))
    late = start[v] + 0.01 * res.latency_cc   # lands well past the start
    res.comm_intervals[k] = (s, late, u, v, b)
    with pytest.raises(TraceValidationError) as exc:
        validate_trace(res, graph, acc, workload=w, segment=False)
    assert exc.value.invariant == "dependency-order"
    assert f"CN {v}" in str(exc.value)


def test_corrupt_memory_overflow_named(sched):
    """An allocation past SRAM capacity fails as memory-capacity, by name."""
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency")
    res.mem_events.append((res.latency_cc, 1e18, 0, "act"))
    with pytest.raises(TraceValidationError) as exc:
        validate_trace(res, graph, acc, workload=w)
    assert exc.value.invariant == "memory-capacity"
    assert "core 0" in str(exc.value)


def test_corrupt_segment_barrier_named(sched):
    """A CN starting before the previous fused stack drains fails as
    segment-monotonicity, by name — the invariant checkpointing needs."""
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency", strict_layers=True)
    layer_of = graph.layer.tolist()
    corrupted = False
    for core, ivs in enumerate(res.core_intervals):
        for k in range(1, len(ivs)):
            s, e, i = ivs[k]
            prev_end = ivs[k - 1][1]
            barrier = max((ee for civ in res.core_intervals
                           for ss, ee, jj in civ
                           if layer_of[jj] < layer_of[i]), default=0.0)
            # a start inside (prev core busy end, stack barrier) keeps
            # core-exclusivity intact but breaks the barrier
            if prev_end < barrier - 1e-3 * res.latency_cc:
                ivs[k] = ((prev_end + barrier) / 2, e, i)
                corrupted = True
                break
        if corrupted:
            break
    assert corrupted, "no corruptible window found"
    with pytest.raises(TraceValidationError) as exc:
        validate_trace(res, graph, acc, workload=w, strict_layers=True)
    assert exc.value.invariant == "segment-monotonicity"
    assert "barrier" in str(exc.value)


def test_corrupt_bus_double_booking_named(sched):
    """Two transfers occupying the shared bus at once fail as
    channel-exclusivity, by name.  A duplicated transfer keeps producer/
    consumer ordering intact (same endpoints), so only the bus resource
    is double-booked."""
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency", segment=False)
    assert res.comm_intervals
    res.comm_intervals.append(res.comm_intervals[0])
    with pytest.raises(TraceValidationError) as exc:
        validate_trace(res, graph, acc, workload=w, segment=False)
    assert exc.value.invariant == "channel-exclusivity"
    assert "shared bus" in str(exc.value)


def test_report_contents(sched):
    w, acc, graph, engine, alloc = sched
    res = engine.schedule(alloc, "latency")
    report = validate_trace(res, graph, acc, workload=w)
    assert report["cns"] == graph.n
    assert report["edges"] > 0
    assert report["channels"] == 1         # flat bus
    assert report["skipped"] == []
    # without the workload the segment partition cannot be re-derived
    report2 = validate_trace(res, graph, acc)
    assert report2["skipped"] == ["segment-monotonicity (needs workload)"]
