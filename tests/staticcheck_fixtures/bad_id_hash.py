"""Fixture: id()/hash() feeding keys — interpreter-run-local values that
must never reach anything content-keyed or persisted."""


def content_key(obj) -> str:
    return str(hash(obj))                # line 6: hash() inside a *key* fn


def build(cfg):
    cache_key = (id(cfg), "v1")          # line 10: id() into a *key* target
    plain = id(cfg)                      # not keyish: fine
    return cache_key, plain
