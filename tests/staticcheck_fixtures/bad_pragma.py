"""Fixture: malformed pragmas — suppressions must name a known rule."""
import time


def a():
    return time.time()  # staticcheck: allow(not-a-rule)


def b():
    return time.time()  # staticcheck: ignore
