"""Fixture: intentional nondeterminism, every site pragma-suppressed —
lints with zero *unallowed* violations on the deterministic tier."""
import time


def heartbeat() -> dict:
    return {"updated_unix": time.time()}  # staticcheck: allow(wall-clock)


def wall_budget() -> float:
    # operator-facing timing, never persisted
    # staticcheck: allow(wall-clock)
    return time.perf_counter()
