"""Fixture: global/unseeded RNG the linter must catch — and the seeded
constructions it must leave alone."""
import os
import random

import numpy as np


def draw(seed: int):
    a = random.random()                  # line 10: process-global RNG
    random.seed(seed)                    # line 11: mutates global state
    b = np.random.rand(3)                # line 12: numpy legacy global
    rng = np.random.default_rng()        # line 13: unseeded constructor
    tok = os.urandom(8)                  # line 14: OS entropy
    good = np.random.default_rng(seed)   # seeded: fine
    also = random.Random(seed)           # seeded: fine
    import jax
    key = jax.random.PRNGKey(seed)       # key-passing API: fine
    return a, b, rng, tok, good, also, key
