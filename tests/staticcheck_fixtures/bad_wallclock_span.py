"""Fixture: wall-clock reads smuggled into sim-time tracer spans.

The two-channel observability contract (docs/ARCHITECTURE.md §13): code
on the sim-time channel records logical ticks or simulated cycles only.
Reading the wall clock inside a sim-time span — or stamping a sim-time
event with wall time — must be flagged; only `repro.obs.realtime` (a
REALTIME-tier module) may bind the wall clock.
"""
import time

from repro.obs.tracing import Tracer


def traced_step(tracer: Tracer):
    with tracer.span("sweep.point"):
        t0 = time.perf_counter()         # line 16: wall-clock in a span
        tracer.observe("wall_s", time.time())   # line 17: wall-clock
    tracer.add_span("step", 0.0, time.perf_counter())  # line 18: wall-clock
    return t0
