"""Fixture: every flavour of wall-clock read the linter must catch."""
import time
from datetime import datetime
from time import perf_counter as pc


def stamp():
    t0 = time.time()                     # line 8: wall-clock
    t1 = time.perf_counter()             # line 9: wall-clock
    now = datetime.now()                 # line 10: wall-clock
    t2 = pc()                            # line 11: wall-clock (aliased)
    time.sleep(0.0)                      # sleeping is not *reading* the clock
    return t0, t1, now, t2
