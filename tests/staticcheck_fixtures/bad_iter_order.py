"""Fixture: set iteration order escaping — and the sorted() uses that
make it deterministic."""


def leak(items):
    seen = {x.name for x in items}
    for name in seen:                    # dict/list iteration is fine; but:
        pass
    for name in {"b", "a"}:              # line 9: set literal iterated
        print(name)
    out = list({x for x in items})       # line 11: set comp materialized
    csv = ",".join(set(items))           # line 12: set serialized
    ok = sorted({x for x in items})      # sorted: fine
    n = len({x for x in items})          # order-insensitive: fine
    return out, csv, ok, n
