"""Fixture: an arrival sampler that reads real time instead of hashing.

The serving contract (`repro.serve.arrivals`) demands pure-hash arrival
gaps — a trace must be a content-addressed value. This is the classic way
to break it: seeding inter-arrival randomness from the wall clock and
stamping arrivals with the host's clock, so the "trace" can never replay.
"""
import random
import time


def sample_arrivals(rate_rps, n_requests):
    t0 = time.time()                               # line 13: wall-clock
    rng = random.Random()                          # line 14: unseeded rng
    arrivals = []
    t = t0
    for _ in range(n_requests):
        t += rng.expovariate(rate_rps)
        arrivals.append(t - time.perf_counter())   # line 19: wall-clock
    return arrivals
