"""Fixture: unpicklable callables crossing the process-pool boundary."""


def top_level(x):
    return x + 1


def launch(pool, xs):
    f1 = pool.submit(lambda x: x + 1, xs[0])      # line 9: lambda submitted
    def helper(x):
        return x * 2
    f2 = pool.submit(helper, xs[1])               # line 12: nested def
    g = lambda x: x - 1
    f3 = pool.apply_async(g, (xs[2],))            # line 14: lambda-named
    f4 = pool.submit(top_level, xs[3])            # module-level: fine
    mapped = map(lambda x: x, xs)                 # plain map(): fine
    return f1, f2, f3, f4, mapped
