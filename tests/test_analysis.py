"""HLO walker + roofline + sharding rules + cost model unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze
from repro.analysis.roofline import Roofline
from repro.sharding.rules import spec_for
from repro.launch.mesh import compat_make_mesh, compat_set_mesh


def test_walker_counts_scanned_dot_flops():
    """A scan of L matmuls must report L x the per-iteration FLOPs (XLA's
    own cost_analysis counts the body once — the walker must not)."""
    L, M, K, N = 7, 32, 48, 16
    W = jnp.ones((L, K, N), jnp.float32)

    def f(x):
        def body(x, w):
            return x @ w @ jnp.ones((N, K), jnp.float32), ()
        x, _ = jax.lax.scan(body, x, W)
        return x

    compiled = jax.jit(f).lower(jnp.ones((M, K))).compile()
    a = analyze(compiled.as_text())
    want = L * (2 * M * K * N + 2 * M * N * K)
    assert a.flops == pytest.approx(want, rel=0.05)
    assert any(t == L for t in a.while_trip_counts.values())


def test_walker_counts_collective_bytes():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device")
    mesh = compat_make_mesh((len(devs),), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(None))).sum() + x.sum()

    x_sh = NamedSharding(mesh, P("d"))
    with compat_set_mesh(mesh):
        compiled = jax.jit(f, in_shardings=(x_sh,)).lower(
            jax.ShapeDtypeStruct((len(devs) * 8, 4), jnp.float32)).compile()
    a = analyze(compiled.as_text())
    assert a.total_collective_bytes > 0


def test_roofline_terms_and_bottleneck():
    r = Roofline(chips=256, flops=197e12, hbm_bytes=10e9,
                 attn_tile_bytes=0.0,
                 collective_bytes=100e9, collective_breakdown={},
                 model_flops=197e12 * 256 * 0.5, xla_flops=0, xla_bytes=0)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(10e9 / 819e9)
    assert r.t_collective == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert 0 < r.mfu < 1


def test_sharding_rules_divisibility_fallback():
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    # divisible: sharded
    assert spec_for(("vocab", None), (512, 16), mesh)[0] == "model"
    # not divisible: replicated
    s = spec_for(("vocab", None), (510, 16), mesh)
    assert len(s) == 0 or s[0] is None
    # combined axes
    s = spec_for(("batch", None), (8, 16), mesh)
    assert s[0] == ("data",) or s[0] == "data"


def test_costmodel_caching_and_feasibility():
    from repro.configs.paper_workloads import resnet18
    from repro.core import CostModel
    from repro.core.cn import identify_cns
    from repro.hw.catalog import mc_hetero
    w = resnet18()
    acc = mc_hetero()
    cm = CostModel(w, acc)
    cns = identify_cns(w, "line")
    c1 = cm.cost(cns[5], 0)
    c2 = cm.cost(cns[5], 0)
    assert c1 is c2  # cached
    # SIMD core cannot run convs
    simd = acc.simd_core_id
    conv_cn = next(c for c in cns if w.layers[c.layer].op == "conv")
    assert cm.cost(conv_cn, simd) is None


def test_zigzag_lite_loma_picks_better_order():
    """C-K dataflows must not pay per-MAC weight reads (order B wins)."""
    from repro.core.zigzag_lite import cn_cost
    from repro.hw.core_model import CoreModel
    core = CoreModel("t", (("C", 32), ("K", 32)), act_mem_bytes=1 << 16,
                     weight_mem_bytes=1 << 17, sram_bw_bits_per_cc=1024)
    c = cn_cost({"K": 64, "C": 64, "OY": 16, "OX": 56, "FY": 3, "FX": 3},
                "conv", core)
    assert c.cycles < c.ideal_cycles * 8  # no catastrophic stall
    assert 0 < c.spatial_util <= 1.0


def test_aimc_flexible_packing():
    from repro.core.zigzag_lite import cn_cost
    from repro.hw.core_model import CoreModel
    core = CoreModel("a", (("C", 128), ("FY", 3), ("FX", 3), ("K", 256)),
                     act_mem_bytes=1 << 14, weight_mem_bytes=1 << 18,
                     core_type="aimc", aimc_cc_per_op=10)
    # 3x3x64 filter = 576 rows <= 1152 -> one activation per output pixel
    c = cn_cost({"K": 64, "C": 64, "OY": 1, "OX": 56, "FY": 3, "FX": 3},
                "conv", core)
    assert c.ideal_cycles == 56 * 10
