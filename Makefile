PY := PYTHONPATH=src python

.PHONY: all lint test tier1 docs coverage coverage-record bench bench-quick \
	bench-full bench-list faults trace

# default flow: static checks, the full suite, the docs gate, and the
# function-coverage floor over the tier-1 suite
all: lint test docs coverage

# determinism linter over src/repro (exit 5 on unallowed violations);
# `--format json` is available for machine consumption
lint:
	$(PY) tools/check_static.py --strict

# full suite (includes the jax model/train/serve substrate)
test:
	$(PY) -m pytest -q

# fast core Stream suite: engine golden equivalence, CN dependency graph,
# scheduler invariants, topology model, exploration session + archspec
# (~seconds, no jax)
tier1:
	$(PY) -m pytest -q -m tier1

# markdown link check over README/ROADMAP/docs/ + executable docstring
# examples (doctest) of the public API surface
docs:
	$(PY) tools/check_docs.py

# function-coverage gate: traces the tier-1 suite with a built-in
# sys.setprofile hook (no coverage/pytest-cov dependency) and fails any
# module dropping below its recorded floor (tools/coverage_baseline.json)
coverage:
	$(PY) tools/check_coverage.py

# refresh the recorded floors after intentionally growing the surface
coverage-record:
	$(PY) tools/check_coverage.py --record

# observability smoke: export Chrome/Perfetto traces (one 4-chiplet
# catalog schedule + one serving-sim run) and the bottleneck report to
# traces/ — open the JSON in chrome://tracing or ui.perfetto.dev
trace:
	$(PY) tools/trace_export.py --out traces

# fault-injection suite: retry/quarantine semantics, crash-safe stores,
# pool-rebuild under worker kills, SIGKILL crash-restart of a shard
faults:
	$(PY) -m pytest -q tests/test_resilience.py

bench:
	$(PY) -m benchmarks.run

# the scheduling benches (GA hot path) + the sweep runtime in quick mode
bench-quick:
	$(PY) -m benchmarks.run --only scheduler_throughput,ga_allocation,exploration,sweep_runtime

bench-full:
	$(PY) -m benchmarks.run --full

# registered bench slugs (a typo'd --only slug is an error, not a no-op)
bench-list:
	$(PY) -m benchmarks.run --list
