PY := PYTHONPATH=src python

.PHONY: test tier1 bench bench-quick bench-full

# full suite (includes the jax model/train/serve substrate)
test:
	$(PY) -m pytest -q

# fast core Stream suite: engine golden equivalence, CN dependency graph,
# scheduler invariants, exploration session + archspec (~seconds, no jax)
tier1:
	$(PY) -m pytest -q -m tier1

bench:
	$(PY) -m benchmarks.run

# the three scheduling benches (GA hot path) in quick mode
bench-quick:
	$(PY) -m benchmarks.run --only scheduler_throughput,ga_allocation,exploration

bench-full:
	$(PY) -m benchmarks.run --full
