PY := PYTHONPATH=src python

.PHONY: test tier1 bench bench-full

# full suite (includes the jax model/train/serve substrate)
test:
	$(PY) -m pytest -q

# fast core Stream suite: engine golden equivalence, CN dependency graph,
# scheduler invariants, exploration session + archspec (~seconds, no jax)
tier1:
	$(PY) -m pytest -q -m tier1

bench:
	$(PY) -m benchmarks.run

bench-full:
	$(PY) -m benchmarks.run --full
