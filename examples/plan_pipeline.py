"""Stream planner on the pod: plan pipeline-parallel training for an
assigned architecture, showing the latency/memory scheduling trade-off that
the paper demonstrates on edge SoCs (Fig. 7) reappearing at datacenter scale
— then run the planned pipeline for real on host devices.

  PYTHONPATH=src python examples/plan_pipeline.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, reduce_config
from repro.core.planner import evaluate_pipeline
from repro.models.module import init_from_specs
from repro.models.zoo import build_param_specs
from repro.train.pipeline import make_pipeline_loss
from repro.launch.mesh import compat_make_mesh, compat_set_mesh

cfg_full = ARCHS["deepseek-67b"]
shape = SHAPES["train_4k"]
print(f"planning {cfg_full.name} x {shape.name} on 256 chips")
for prio in ("latency", "memory"):
    for ns, nm in ((4, 8), (4, 32), (8, 32)):
        p = evaluate_pipeline(cfg_full, shape, n_stages=ns,
                              chips_per_stage=256 // ns, n_microbatches=nm,
                              priority=prio)
        print(f"  {prio:8s} stages={ns} micro={nm:2d}: "
              f"step={p.est_step_s:7.2f}s peak={p.est_peak_bytes / 2**30:6.1f}GB "
              f"util={p.schedule.utilization().mean():.2f}")

print("\nexecuting a 2-stage pipeline on host devices (reduced config):")
cfg = reduce_config(ARCHS["llama3.2-3b"], n_layers=4)
mesh = compat_make_mesh((2, 2), ("pipe", "data"))
params = init_from_specs(build_param_specs(cfg), jax.random.PRNGKey(0))
params["layers"] = jax.tree.map(lambda a: a.reshape((2, 2) + a.shape[1:]),
                                params["layers"])
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
         "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
loss_fn = make_pipeline_loss(cfg, mesh, n_stages=2, n_microbatches=2)
with compat_set_mesh(mesh):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
print(f"pipeline loss={float(loss):.4f}; grads flow through ppermute: "
      f"{all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))}")
