"""Batched serving example: prefill + decode over a KV cache for several
concurrent requests (reduced llama config).

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "llama3.2-3b", "--requests", "4", "--max-new", "16",
          "--prompt-len", "32"])
