"""Distributed sweep in miniature: shard a design space across "machines"
(here: directories), merge the shard stores, and stream an early-stopping
sweep — all on the runtime in `repro.api.distributed` / `repro.api.policies`.

  PYTHONPATH=src python examples/distributed_sweep.py [work_dir]

In a real deployment each `run_shard` call is a separate process on a
separate machine (`python tools/run_shard.py sweep.json --shard K/N`) and
the merge happens wherever the shard stores land
(`python tools/merge_stores.py merged shard0 shard1 ...`).
"""
import os
import sys
import tempfile

from repro.api import (DesignSpace, ExplorationSession, GAConfig,
                       PlateauPolicy, ResultStore, build_manifest, run_shard)
from repro.hw.catalog import EXPLORATION_ARCHITECTURES

N_SHARDS = 2

space = DesignSpace(
    workloads=["squeezenet", "fsrcnn"],
    archs=EXPLORATION_ARCHITECTURES,
    granularities=["layer", ("tile", 32, 1)],
    ga=GAConfig(pop_size=8, generations=5),
)

work_dir = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp()

# 1. freeze the space into a manifest; nearest-arch ordering keeps each
#    contiguous shard inside one architecture-similarity neighborhood
manifest = build_manifest(space, order="nearest-arch")
manifest_path = manifest.save(os.path.join(work_dir, "sweep.json"))
print(f"manifest: {len(manifest)} points -> {manifest_path}")

# 2. run each shard in its own session + store (one per machine, really)
shard_dirs = []
for k in range(N_SHARDS):
    shard_dir = os.path.join(work_dir, f"shard{k}")
    sweep = run_shard(manifest_path, cache_dir=shard_dir, shard=(k, N_SHARDS))
    print(f"shard {k}/{N_SHARDS}: {len(sweep)} points, "
          f"{sweep.n_scheduled} scheduled, {sweep.wall_s:.1f}s")
    shard_dirs.append(shard_dir)

# 3. merge: the record set is bit-identical to a serial run of the space
merged = ResultStore.merge(*shard_dirs,
                           cache_dir=os.path.join(work_dir, "merged"))
serial = ExplorationSession().run(space)
assert {(r.key, r.edp) for r in merged.values()} == \
       {(r.key, r.edp) for r in serial.records}
print(f"merged {N_SHARDS} shard stores: {len(merged)} records, "
      "bit-identical to the serial sweep")

# 4. streaming: a fresh session over the merged store stops on plateau
session = ExplorationSession(cache_dir=os.path.join(work_dir, "merged"))
policy = PlateauPolicy(metric="edp", patience=6)
n = 0
for record in session.run_async(space, order="nearest-arch",
                                policies=[policy]):
    n += 1
print(f"streamed {n}/{len(serial)} records "
      f"(stop: {policy.reason or 'stream exhausted'})")
best = min(serial.records, key=lambda r: r.edp)
print(f"best EDP: {best.arch} / {best.workload} / {best.granularity} "
      f"= {best.edp:.3e}")
