"""End-to-end training driver: a ~100M-class llama on synthetic data with
checkpointing and resume (reduced further by default so it runs on CPU in
a few minutes; pass --full-100m on a real machine).

  PYTHONPATH=src python examples/train_lm.py            # ~10M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full_100m:   # ~100M params: 12 layers x d_model 768
        argv = ["--arch", "llama3.2-3b", "--smoke", "--d-model", "768",
                "--layers", "12", "--batch", "16", "--seq", "512"]
    else:                # ~10M params: CPU-friendly
        argv = ["--arch", "llama3.2-3b", "--smoke", "--d-model", "256",
                "--layers", "4", "--batch", "8", "--seq", "128"]
    argv += ["--steps", str(args.steps), "--ckpt-dir", args.ckpt_dir,
             "--ckpt-every", "100"]
    train_main(argv)
