"""Quickstart: Stream design-space exploration in ~20 lines.

Explores ResNet-18 on the heterogeneous quad-core accelerator through an
`ExplorationSession`, comparing traditional layer-by-layer scheduling
against fine-grained layer fusion (the paper's central experiment), then
prints the best schedule's stats.  (`repro.core.explore` remains as a
one-call wrapper over a default session.)

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ExplorationSession
from repro.configs.paper_workloads import resnet18
from repro.hw.catalog import mc_hetero

workload = resnet18()
accelerator = mc_hetero()
print(f"workload: {workload}")
print(f"accelerator: {accelerator.name} ({accelerator.n_cores} cores)")

session = ExplorationSession()   # owns the graph/engine caches
lbl = session.explore(workload, accelerator, granularity="layer",
                      objective="edp", pop_size=10, generations=6)
fused = session.explore(workload, accelerator, granularity=("tile", 32, 1),
                        objective="edp", pop_size=10, generations=6)

for name, r in (("layer-by-layer", lbl), ("layer-fused", fused)):
    print(f"\n{name}:")
    print(f"  latency  : {r.latency_cc:12.3e} cc")
    print(f"  energy   : {r.energy_pj / 1e6:12.1f} uJ")
    print(f"  EDP      : {r.edp:12.3e}")
    print(f"  peak mem : {r.peak_mem_bytes / 1024:12.1f} KB")
    print(f"  allocation: {r.allocation.tolist()}")
    print(f"  runtime  : {r.runtime_s:.2f} s (CNs: {len(r.graph.cns)})")

print(f"\nEDP reduction from layer fusion: {lbl.edp / fused.edp:.1f}x "
      f"(paper reports up to 30x on this architecture class)")
