"""Architecture exploration (paper Sec. V in miniature): two DNNs across the
seven iso-area accelerators, layer-by-layer vs layer-fused, EDP-optimized —
declared as one `DesignSpace` and executed by an `ExplorationSession`.

Pass a directory as the first argument to persist results: a second run
against the same store schedules zero new points.

  PYTHONPATH=src python examples/explore_architectures.py [store_dir]
"""
import sys

import numpy as np

from repro.api import DesignSpace, ExplorationSession, GAConfig
from repro.hw.catalog import EXPLORATION_ARCHITECTURES

space = DesignSpace(
    workloads=["resnet18", "squeezenet"],      # names from the paper registry
    archs=EXPLORATION_ARCHITECTURES,
    granularities=["layer", ("tile", 32, 1)],
    ga=GAConfig(pop_size=8, generations=5),
)
session = ExplorationSession(cache_dir=sys.argv[1] if len(sys.argv) > 1 else None)
sweep = session.run(space)
print(f"{len(sweep)} points: {sweep.n_scheduled} scheduled, "
      f"{sweep.n_from_store} from store, {sweep.wall_s:.1f}s\n")

by_cell = {(r.arch, r.workload, r.granularity): r for r in sweep.records}
print(f"{'architecture':12s} {'network':12s} {'EDP(lbl)':>11s} "
      f"{'EDP(fused)':>11s} {'gain':>6s}")
for arch_name in EXPLORATION_ARCHITECTURES:
    gains = []
    for net_name in space.workloads:
        lbl = by_cell[(arch_name, net_name, "layer")]
        fused = by_cell[(arch_name, net_name, "tile32x1")]
        gain = lbl.edp / fused.edp
        gains.append(gain)
        print(f"{arch_name:12s} {net_name:12s} {lbl.edp:11.3e} "
              f"{fused.edp:11.3e} {gain:5.1f}x")
    print(f"{arch_name:12s} {'geomean':12s} {'':23s} "
          f"{np.exp(np.mean(np.log(gains))):5.1f}x")

best = sweep.best("edp")
print(f"\nbest EDP point: {best.arch} / {best.workload} / {best.granularity} "
      f"(EDP {best.edp:.3e})")
front = sweep.pareto(("latency_cc", "energy_pj"))
print(f"latency/energy pareto front: "
      + ", ".join(f"{r.arch}/{r.workload}/{r.granularity}" for r in front))
