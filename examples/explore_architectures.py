"""Architecture exploration (paper Sec. V in miniature): two DNNs across the
seven iso-area accelerators, layer-by-layer vs layer-fused, EDP-optimized.

  PYTHONPATH=src python examples/explore_architectures.py
"""
import numpy as np

from repro.configs.paper_workloads import EXPLORATION_WORKLOADS
from repro.core import explore
from repro.hw.catalog import EXPLORATION_ARCHITECTURES

nets = {k: EXPLORATION_WORKLOADS[k] for k in ("resnet18", "squeezenet")}
print(f"{'architecture':12s} {'network':12s} {'EDP(lbl)':>11s} "
      f"{'EDP(fused)':>11s} {'gain':>6s}")
for arch_name, arch_fn in EXPLORATION_ARCHITECTURES.items():
    gains = []
    for net_name, net_fn in nets.items():
        acc, w = arch_fn(), net_fn()
        lbl = explore(w, acc, granularity="layer", pop_size=8, generations=5)
        fused = explore(w, acc, granularity=("tile", 32, 1), pop_size=8,
                        generations=5)
        gain = lbl.edp / fused.edp
        gains.append(gain)
        print(f"{arch_name:12s} {net_name:12s} {lbl.edp:11.3e} "
              f"{fused.edp:11.3e} {gain:5.1f}x")
    print(f"{arch_name:12s} {'geomean':12s} {'':23s} "
          f"{np.exp(np.mean(np.log(gains))):5.1f}x")
